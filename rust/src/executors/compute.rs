//! Compute Executor (§3.3.1): a DAG-aware priority queue drained by a
//! configurable pool of threads, with OOM retry.
//!
//! "The Compute Executor can prioritize tasks in its queue based on
//! different configurable schemes that can take into account a wide
//! variety of factors, including where in the query graph the task came
//! from and the memory tier that the input data resides in. Each
//! Compute Executor thread controls a separate CUDA stream" — here,
//! each thread issues PJRT executions independently (the CPU client
//! runs them on its own pool, our stream analog).
//!
//! ## Residency-aware ordering
//!
//! Tasks declare their input holders ([`Task::inputs`]); the queue
//! scores each submission as `base_priority + residency_bonus`, where
//! the bonus rewards device-resident inputs and penalizes spilled ones
//! (the paper's "memory tier that the input data resides in"). The
//! Data-Movement executor calls
//! [`TaskQueue::notify_residency_changed`] after every completed
//! promotion/demotion; the queue then lazily re-ranks the affected
//! queued tasks on the next pop instead of re-sorting on every pop —
//! closing the §3.3.1 feedback loop in the reverse direction of
//! [`TaskQueue::op_priorities`]. Each re-rank ages penalized entries
//! (halving their distance to the full device bonus), so a
//! spilled-input task can be delayed but never starved: after at most
//! ~log2(penalty) re-ranks it ties fresh device-resident tasks and wins
//! on FIFO order. With the bonus table zeroed (the default config) the
//! queue is byte-for-byte the plain `priority + seq` heap.
//!
//! Failed tasks with retryable errors (device OOM, reservation timeout,
//! pinned exhaustion) are re-queued with a decayed priority; the
//! operator's memory history is updated by the task itself.

use std::collections::{BinaryHeap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::sync::{ranks, OrderedCondvar, OrderedMutex};
use std::time::Duration;

use crate::exec::{Task, WorkerCtx};
use crate::memory::ResidencySnapshot;
use crate::metrics::Metrics;
use crate::Error;

const MAX_ATTEMPTS: u32 = 6;

/// The §3.3.1 input-tier bonus table (see
/// [`crate::config::WorkerConfig`]: `residency_bonus_device`,
/// `residency_penalty_spilled`, `residency_rerank_batch`). All-zero —
/// the default — disables residency-aware ordering entirely.
#[derive(Clone, Copy, Debug)]
pub struct ResidencyBonus {
    /// Added (scaled by the device-resident byte fraction) to tasks
    /// whose inputs already sit in device memory.
    pub device_bonus: i64,
    /// Subtracted (scaled by the spilled byte fraction) from tasks
    /// whose inputs must come back from disk first.
    pub spilled_penalty: i64,
    /// Max queued tasks re-scored per re-rank pass; affected tasks
    /// beyond the cap keep their stale rank until the next pop.
    pub rerank_batch: usize,
}

impl Default for ResidencyBonus {
    fn default() -> Self {
        ResidencyBonus { device_bonus: 0, spilled_penalty: 0, rerank_batch: 32 }
    }
}

impl ResidencyBonus {
    pub fn is_enabled(&self) -> bool {
        self.device_bonus != 0 || self.spilled_penalty != 0
    }

    /// Score a residency snapshot at `age` re-rank generations.
    ///
    /// Age 0 yields `device_bonus*dev_frac - spilled_penalty*spill_frac`;
    /// every re-rank halves the distance to the full `device_bonus`, so
    /// the bonus is always in `[-spilled_penalty, device_bonus]` and a
    /// fully-device snapshot scores `device_bonus` at every age —
    /// aged spilled work catches up to hot work, never overtakes it.
    pub fn score(&self, snap: &ResidencySnapshot, age: u32) -> i64 {
        if !self.is_enabled() {
            return 0;
        }
        let raw = (self.device_bonus as f64 * snap.device_frac()
            - self.spilled_penalty as f64 * snap.spilled_frac()) as i64;
        self.age_score(raw, age)
    }

    /// Decay a raw age-0 score toward the device bonus — the one place
    /// the decay curve lives, so the re-rank pass can derive an aged
    /// score from an already-taken snapshot instead of re-snapshotting
    /// every input holder a second time.
    pub fn age_score(&self, raw: i64, age: u32) -> i64 {
        let gap = self.device_bonus.saturating_sub(raw);
        self.device_bonus - (gap >> age.min(62))
    }
}

struct Queued {
    /// Effective priority: `task.priority + bonus` at scoring time.
    priority: i64,
    /// FIFO tiebreak: smaller sequence first.
    seq: u64,
    /// Re-rank generations survived (decays the spilled penalty).
    age: u32,
    /// The age-0 score this entry was last rated against. A re-rank
    /// whose fresh age-0 score drops below it means the inputs'
    /// residency *worsened* since the entry last looked — the decay
    /// clock resets so the new penalty binds (soundness gap #1).
    base_score: i64,
    task: Task,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq)) // max-heap: older first on tie
    }
}

/// The shared queue. The Pre-load and Data-Movement Executors hold
/// references to inspect it (Insight B), and register
/// [`crate::memory::PressureEvent`] listeners so pre-loadable
/// submissions wake them instead of being discovered by polling.
pub struct TaskQueue {
    heap: OrderedMutex<BinaryHeap<Queued>>,
    ready: OrderedCondvar,
    seq: AtomicU64,
    /// Tasks currently executing (quiescence detection).
    in_flight: AtomicU64,
    /// Marked dirty when a task with a prefetch hint is submitted.
    listeners: OrderedMutex<Vec<Arc<crate::memory::PressureEvent>>>,
    /// Input-tier bonus table (all-zero = residency ordering off).
    bonus: ResidencyBonus,
    /// Holder ids whose residency changed since the last re-rank pass
    /// (fed by the Data-Movement executor's completed moves).
    dirty_holders: OrderedMutex<HashSet<usize>>,
    /// Stable resume point of a capped re-rank pass: the submission
    /// *seq* where the last pass stopped. Relevant entries are scanned
    /// in seq order starting here, so the rotation addresses the same
    /// tasks across passes regardless of how `BinaryHeap::into_vec`
    /// happens to permute the heap — the bounded-starvation guarantee
    /// holds at any `rerank_batch`.
    rerank_cursor: AtomicU64,
    metrics: Arc<Metrics>,
}

impl Default for TaskQueue {
    fn default() -> Self {
        TaskQueue {
            heap: OrderedMutex::new(ranks::SCHED_HEAP, "sched.heap", BinaryHeap::new()),
            ready: OrderedCondvar::new(),
            seq: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            listeners: OrderedMutex::new(
                ranks::SCHED_LISTENERS,
                "sched.listeners",
                Vec::new(),
            ),
            bonus: ResidencyBonus::default(),
            dirty_holders: OrderedMutex::new(
                ranks::SCHED_DIRTY_HOLDERS,
                "sched.dirty_holders",
                HashSet::new(),
            ),
            rerank_cursor: AtomicU64::new(0),
            metrics: Arc::new(Metrics::default()),
        }
    }
}

impl TaskQueue {
    pub fn new() -> Arc<TaskQueue> {
        Arc::new(TaskQueue::default())
    }

    /// A queue with residency-aware ordering: `bonus` scores inputs at
    /// submit time and `metrics` receives the
    /// `sched.residency_rerank_total` / `sched.spill_stall_avoided`
    /// gauges.
    pub fn with_residency(bonus: ResidencyBonus, metrics: Arc<Metrics>) -> Arc<TaskQueue> {
        Arc::new(TaskQueue { bonus, metrics, ..TaskQueue::default() })
    }

    /// Register an event to be marked dirty whenever a task carrying a
    /// [`crate::exec::task::Prefetch`] is submitted (queue
    /// introspection without a polling loop).
    pub fn add_listener(&self, event: Arc<crate::memory::PressureEvent>) {
        self.listeners.lock().push(event);
    }

    /// The Data-Movement executor completed a promotion or demotion on
    /// `holder_id`: queued tasks reading that holder are re-ranked
    /// lazily on the next pop (no re-sort per pop, no re-sort per
    /// notification).
    pub fn notify_residency_changed(&self, holder_id: usize) {
        if !self.bonus.is_enabled() {
            return;
        }
        self.dirty_holders.lock().insert(holder_id);
    }

    /// Base priority plus the residency bonus, scaled by the task's
    /// session weight (PR 8): a weight-10 interactive query's
    /// device-resident inputs outrank a weight-1 batch query's at equal
    /// base priority, so the shared queue serves latency-sensitive work
    /// first exactly where residency already decides ties. Weight 1
    /// (the default) reproduces single-query scoring bit for bit.
    fn effective_priority(&self, task: &Task, age: u32) -> i64 {
        if !self.bonus.is_enabled() || task.inputs.is_empty() {
            return task.priority;
        }
        task.priority
            + task.weight.max(1) * self.bonus.score(&task.input_residency(), age)
    }

    pub fn submit(&self, task: Task) {
        let prefetchable = task.prefetch.is_some();
        let score = self.effective_priority(&task, 0);
        let q = Queued {
            priority: score,
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            age: 0,
            base_score: score,
            task,
        };
        {
            let mut heap = self.heap.lock();
            heap.push(q);
            self.ready.notify_one(&heap);
        }
        if prefetchable {
            // listeners (124) held across mark_queue's pressure.state
            // (390) acquisition — a declared descent
            for ev in self.listeners.lock().iter() {
                ev.mark_queue();
            }
        }
    }

    /// Apply pending residency notifications to the queued tasks: up to
    /// `bonus.rerank_batch` relevant entries (inputs intersect the
    /// dirty holder set, or already carrying a penalty that must age)
    /// are re-scored per pass; the rest keep their rank until the next
    /// pop. The heap is torn down and rebuilt (O(n)) only when a
    /// relevant entry exists.
    ///
    /// Two soundness rules (PR-4 review gaps):
    ///
    /// * An entry whose inputs got **colder** (its fresh age-0 score
    ///   drops below the `base_score` it was last rated against) has
    ///   its decay clock reset — the spilled penalty binds again
    ///   instead of riding on age earned while the inputs were hot.
    ///   Comparing against `base_score` (not the decayed rank) keeps a
    ///   merely *re-notified* unchanged holder from resetting decay.
    /// * Relevant entries are scanned in **submission-seq order** from
    ///   a seq-valued cursor, not by position in the transient
    ///   `into_vec` permutation, so a capped pass resumes at the same
    ///   logical task next time and every relevant entry is served
    ///   before any is re-aged (bounded starvation at any batch size).
    fn maybe_rerank(&self, heap: &mut BinaryHeap<Queued>) {
        if !self.bonus.is_enabled() || heap.is_empty() {
            return;
        }
        let dirty: HashSet<usize> = {
            let mut d = self.dirty_holders.lock();
            if d.is_empty() {
                return;
            }
            std::mem::take(&mut *d)
        };
        // entries sitting below their base carry a spilled penalty:
        // age those even when their own holder didn't move, so a
        // starved task's rank keeps rising toward the device bonus
        let is_relevant = |q: &Queued| {
            q.priority < q.task.priority
                || q.task.inputs.iter().any(|h| dirty.contains(&h.id()))
        };
        // cheap pre-scan: the common case (movement on a holder no
        // queued task reads) must not pay the heap rebuild
        if !heap.iter().any(|q| is_relevant(q)) {
            return;
        }
        let top_before = heap.peek().map(|q| q.seq);
        let mut entries: Vec<Queued> = std::mem::take(heap).into_vec();
        // (seq, index) of every relevant entry, rotated to resume at
        // the stable cursor seq
        let mut relevant: Vec<(u64, usize)> = entries
            .iter()
            .enumerate()
            .filter(|(_, q)| is_relevant(q))
            .map(|(i, q)| (q.seq, i))
            .collect();
        relevant.sort_unstable();
        let cursor = self.rerank_cursor.load(Ordering::Relaxed);
        let start = relevant.partition_point(|&(seq, _)| seq < cursor);
        let mut rescored = 0u64;
        let mut deferred = false;
        let mut last_seq = cursor;
        for k in 0..relevant.len() {
            let (seq, idx) = relevant[(start + k) % relevant.len()];
            if rescored as usize >= self.bonus.rerank_batch {
                // resume at this task next pass, and keep the dirty set
                // so the next pop continues serving the unreached tail
                deferred = true;
                self.rerank_cursor.store(seq, Ordering::Relaxed);
                break;
            }
            let q = &mut entries[idx];
            // one residency snapshot per entry: the aged score is
            // derived from the fresh one (same decay curve), not
            // re-snapshotted — input holders are locked once, not twice
            let fresh = self.effective_priority(&q.task, 0);
            if fresh < q.base_score {
                // inputs worsened since this entry was last scored:
                // restart the penalty clock at the new, colder truth
                // instead of letting age earned while hot erase it
                q.age = 0;
                q.priority = fresh;
            } else {
                q.age = q.age.saturating_add(1);
                q.priority = if q.task.inputs.is_empty() {
                    fresh
                } else {
                    // (fresh - base) is exactly weight * raw_score, so
                    // dividing by weight recovers the raw age-0 score the
                    // decay curve operates on; the weight re-applies
                    // after aging, keeping the decay endpoint at
                    // weight * device_bonus for every session.
                    let w = q.task.weight.max(1);
                    q.task.priority
                        + w * self.bonus.age_score((fresh - q.task.priority) / w, q.age)
                };
            }
            q.base_score = fresh;
            last_seq = seq;
            rescored += 1;
        }
        if deferred {
            self.dirty_holders.lock().extend(dirty);
        } else {
            // full pass: rotate past the last task served so future
            // capped passes keep round-robining instead of re-serving
            // the head
            self.rerank_cursor.store(last_seq.wrapping_add(1), Ordering::Relaxed);
        }
        *heap = BinaryHeap::from(entries);
        self.metrics.gauge("sched.residency_rerank_total").add(rescored as i64);
        if heap.peek().map(|q| q.seq) != top_before {
            // the re-rank changed which task runs next: a pop that
            // would have stalled on cold inputs now runs hot work
            self.metrics.gauge("sched.spill_stall_avoided").add(1);
        }
    }

    fn pop(&self, timeout: Duration) -> Option<Task> {
        let deadline = std::time::Instant::now() + timeout;
        let mut heap = self.heap.lock();
        loop {
            self.maybe_rerank(&mut heap);
            if let Some(q) = heap.pop() {
                self.in_flight.fetch_add(1, Ordering::AcqRel);
                return Some(q.task);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.ready.wait_timeout(heap, deadline - now);
            heap = guard;
        }
    }

    /// Pop the next task without blocking or touching the in-flight
    /// accounting — the external single-threaded driver API (benches,
    /// deterministic test harnesses). Pending residency re-ranks are
    /// applied first, exactly as on the executor path.
    pub fn try_pop(&self) -> Option<Task> {
        let mut heap = self.heap.lock();
        self.maybe_rerank(&mut heap);
        heap.pop().map(|q| q.task)
    }

    fn task_done(&self) {
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn len(&self) -> usize {
        self.heap.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Queue fully drained and nothing executing.
    pub fn quiescent(&self) -> bool {
        let heap = self.heap.lock();
        heap.is_empty() && self.in_flight.load(Ordering::Acquire) == 0
    }

    /// Visit every queued (not in-flight) task — the inspection hook
    /// the Pre-load and Data-Movement Executors use. Unordered.
    pub fn for_each_queued(&self, mut f: impl FnMut(&Task)) {
        let heap = self.heap.lock();
        for q in heap.iter() {
            f(&q.task);
        }
    }

    /// Highest queued priority per (query, operator) pair
    /// (Data-Movement Executor: spill holders feeding imminent tasks
    /// last, promote them first). Keyed by qid so two concurrent
    /// queries' same-numbered plan nodes never share a priority slot.
    pub fn op_priorities(&self) -> std::collections::HashMap<(u64, usize), i64> {
        let heap = self.heap.lock();
        let mut m = std::collections::HashMap::new();
        for q in heap.iter() {
            let e = m.entry((q.task.qid, q.task.op)).or_insert(i64::MIN);
            *e = (*e).max(q.task.priority);
        }
        m
    }
}

/// The executor: `threads` workers draining the queue.
///
/// Counters are kept twice: lifetime totals (`executed`, `retries` —
/// cheap atomics, cluster-level gauges) and a per-qid map so concurrent
/// queries report stats without bleeding into each other. Failures are
/// a per-qid map too: query A's permanent failure must abort A alone,
/// never a query B that shares the executor.
pub struct ComputeExecutor {
    queue: Arc<TaskQueue>,
    shutdown: Arc<AtomicBool>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    executed: Arc<AtomicU64>,
    retries: Arc<AtomicU64>,
    /// qid -> (tasks executed, retries).
    per_query: Arc<Mutex<std::collections::HashMap<u64, (u64, u64)>>>,
    /// First non-retryable failure per query (aborts that query only).
    failures: Arc<Mutex<std::collections::HashMap<u64, Error>>>,
}

impl ComputeExecutor {
    pub fn start(ctx: WorkerCtx, queue: Arc<TaskQueue>, threads: usize) -> Arc<ComputeExecutor> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let ex = Arc::new(ComputeExecutor {
            queue: queue.clone(),
            shutdown: shutdown.clone(),
            handles: Mutex::new(Vec::new()),
            executed: Arc::new(AtomicU64::new(0)),
            retries: Arc::new(AtomicU64::new(0)),
            per_query: Arc::new(Mutex::new(std::collections::HashMap::new())),
            failures: Arc::new(Mutex::new(std::collections::HashMap::new())),
        });
        let mut handles = Vec::new();
        for t in 0..threads.max(1) {
            let queue = queue.clone();
            let stop = shutdown.clone();
            let ctx = ctx.clone();
            let executed = ex.executed.clone();
            let retries = ex.retries.clone();
            let per_query = ex.per_query.clone();
            let failures = ex.failures.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("theseus-compute-{}-{t}", ctx.worker_id))
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            let mut task = match queue.pop(Duration::from_millis(20)) {
                                Some(t) => t,
                                None => continue,
                            };
                            let r = (task.run)(&ctx);
                            queue.task_done();
                            match r {
                                Ok(()) => {
                                    executed.fetch_add(1, Ordering::Relaxed);
                                    per_query
                                        .lock()
                                        .unwrap()
                                        .entry(task.qid)
                                        .or_insert((0, 0))
                                        .0 += 1;
                                }
                                Err(e) if e.is_retryable() && task.attempts < MAX_ATTEMPTS => {
                                    retries.fetch_add(1, Ordering::Relaxed);
                                    per_query
                                        .lock()
                                        .unwrap()
                                        .entry(task.qid)
                                        .or_insert((0, 0))
                                        .1 += 1;
                                    task.attempts += 1;
                                    // decay priority so other work makes
                                    // room (the movement executor gets
                                    // a chance to spill)
                                    task.priority -= 10 * task.attempts as i64;
                                    // brief backoff before re-queue
                                    std::thread::sleep(Duration::from_millis(
                                        2 << task.attempts.min(5),
                                    ));
                                    queue.submit(task);
                                }
                                Err(e) => {
                                    log::error!(
                                        "task q{} op {} failed permanently: {e}",
                                        task.qid,
                                        task.op
                                    );
                                    failures.lock().unwrap().entry(task.qid).or_insert(e);
                                }
                            }
                        }
                    })
                    .expect("spawn compute"),
            );
        }
        *ex.handles.lock().unwrap() = handles;
        ex
    }

    pub fn queue(&self) -> &Arc<TaskQueue> {
        &self.queue
    }

    pub fn executed(&self) -> u64 {
        self.executed.load(Ordering::Relaxed)
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Tasks executed for one query.
    pub fn executed_for(&self, qid: u64) -> u64 {
        self.per_query.lock().unwrap().get(&qid).map_or(0, |v| v.0)
    }

    /// Retries charged to one query.
    pub fn retries_for(&self, qid: u64) -> u64 {
        self.per_query.lock().unwrap().get(&qid).map_or(0, |v| v.1)
    }

    /// Drop per-query counters and any unclaimed failure once the query
    /// driver has assembled its stats (the map stays bounded under a
    /// long-lived serving process).
    pub fn clear_query(&self, qid: u64) {
        self.per_query.lock().unwrap().remove(&qid);
        self.failures.lock().unwrap().remove(&qid);
    }

    /// Any query's first permanent failure, if any (take clears it).
    /// Single-query harnesses and tests use this; the multi-query
    /// driver path uses [`ComputeExecutor::take_failure_for`].
    pub fn take_failure(&self) -> Option<Error> {
        let mut f = self.failures.lock().unwrap();
        let qid = f.keys().next().copied()?;
        f.remove(&qid)
    }

    /// First permanent failure charged to `qid`, if any (take clears
    /// it). Failures of concurrent queries are left untouched.
    pub fn take_failure_for(&self, qid: u64) -> Option<Error> {
        self.failures.lock().unwrap().remove(&qid)
    }

    pub fn has_failure(&self) -> bool {
        !self.failures.lock().unwrap().is_empty()
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ComputeExecutor {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    fn task(op: usize, prio: i64, f: impl Fn(&WorkerCtx) -> crate::Result<()> + Send + Sync + 'static) -> Task {
        Task::new(op, prio, Arc::new(f))
    }

    #[test]
    fn priority_order_with_fifo_ties() {
        let q = TaskQueue::new();
        let order = Arc::new(Mutex::new(Vec::new()));
        for (op, prio) in [(0usize, 10i64), (1, 30), (2, 10), (3, 20)] {
            let order = order.clone();
            q.submit(task(op, prio, move |_| {
                order.lock().unwrap().push(op);
                Ok(())
            }));
        }
        // drain single-threaded for determinism
        let ctx = WorkerCtx::test();
        while let Some(t) = q.pop(Duration::from_millis(1)) {
            (t.run)(&ctx).unwrap();
            q.task_done();
        }
        assert_eq!(*order.lock().unwrap(), vec![1, 3, 0, 2]);
    }

    #[test]
    fn executor_runs_everything() {
        let q = TaskQueue::new();
        let counter = Arc::new(AtomicU32::new(0));
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 4);
        for i in 0..100 {
            let c = counter.clone();
            q.submit(task(i % 5, i as i64, move |_| {
                c.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }));
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !q.quiescent() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(ex.executed(), 100);
        assert!(!ex.has_failure());
        ex.stop();
    }

    #[test]
    fn retryable_errors_retry_then_succeed() {
        let q = TaskQueue::new();
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 2);
        let fails = Arc::new(AtomicU32::new(2)); // fail twice, then ok
        let done = Arc::new(AtomicU32::new(0));
        let f2 = fails.clone();
        let d2 = done.clone();
        q.submit(task(0, 0, move |_| {
            if f2.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                .is_ok()
            {
                Err(Error::DeviceOom { requested: 1, capacity: 0, in_use: 0 })
            } else {
                d2.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
        }));
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while done.load(Ordering::Relaxed) == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(done.load(Ordering::Relaxed), 1);
        assert!(ex.retries() >= 2);
        assert!(!ex.has_failure());
        ex.stop();
    }

    #[test]
    fn permanent_failure_is_captured() {
        let q = TaskQueue::new();
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 1);
        q.submit(task(0, 0, |_| Err(Error::internal("boom"))));
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !ex.has_failure() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let e = ex.take_failure().unwrap();
        assert!(e.to_string().contains("boom"));
        ex.stop();
    }

    #[test]
    fn retry_gives_up_after_max_attempts() {
        let q = TaskQueue::new();
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 1);
        q.submit(task(0, 0, |_| {
            Err(Error::DeviceOom { requested: 1, capacity: 0, in_use: 0 })
        }));
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !ex.has_failure() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(ex.has_failure(), "should surface OOM after max retries");
        ex.stop();
    }

    #[test]
    fn queue_inspection_sees_pending_tasks() {
        let q = TaskQueue::new();
        q.submit(task(7, 100, |_| Ok(())));
        q.submit(task(7, 50, |_| Ok(())));
        q.submit(task(2, 80, |_| Ok(())));
        let mut seen = 0;
        q.for_each_queued(|t| {
            assert!(t.op == 7 || t.op == 2);
            seen += 1;
        });
        assert_eq!(seen, 3);
        let prios = q.op_priorities();
        assert_eq!(prios[&(0, 7)], 100);
        assert_eq!(prios[&(0, 2)], 80);
    }

    #[test]
    fn op_priorities_scoped_per_query() {
        // Two queries sharing the queue: the same op id must keep a
        // separate priority slot per qid (no cross-query override).
        let q = TaskQueue::new();
        q.submit(task(7, 100, |_| Ok(())).with_query(1, 1));
        q.submit(task(7, 900, |_| Ok(())).with_query(2, 1));
        let prios = q.op_priorities();
        assert_eq!(prios[&(1, 7)], 100);
        assert_eq!(prios[&(2, 7)], 900);
    }

    #[test]
    fn per_query_counters_and_failures_do_not_bleed() {
        let q = TaskQueue::new();
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 2);
        for _ in 0..3 {
            q.submit(task(0, 0, |_| Ok(())).with_query(1, 1));
        }
        q.submit(task(0, 0, |_| Ok(())).with_query(2, 1));
        q.submit(task(1, 0, |_| Err(Error::internal("q2 boom"))).with_query(2, 1));
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while (!q.quiescent() || ex.executed() < 4) && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(ex.executed_for(1), 3);
        assert_eq!(ex.executed_for(2), 1);
        assert_eq!(ex.executed(), 4, "lifetime total sums the queries");
        // q2's failure is invisible to q1's scope...
        assert!(ex.take_failure_for(1).is_none());
        // ...and claimable exactly once by q2's
        assert!(ex.take_failure_for(2).unwrap().to_string().contains("q2 boom"));
        assert!(!ex.has_failure());
        ex.clear_query(1);
        assert_eq!(ex.executed_for(1), 0, "cleared scope reads empty");
        ex.stop();
    }

    #[test]
    fn session_weight_scales_residency_bonus() {
        // Equal base priority, both device-resident: the weight-5
        // session's bonus (5*50) beats the weight-1 session's (50) even
        // though the weight-1 task was submitted first.
        let env = MemEnv::test(1 << 20);
        let dev = device_holder(&env);
        let q = TaskQueue::with_residency(bonus(), Arc::new(crate::metrics::Metrics::default()));
        q.submit(task(1, 1000, |_| Ok(())).with_input(dev.clone()).with_query(1, 1));
        q.submit(task(2, 1000, |_| Ok(())).with_input(dev).with_query(2, 5));
        assert_eq!(q.try_pop().unwrap().op, 2, "weighted session wins");
        assert_eq!(q.try_pop().unwrap().op, 1);
    }

    // ---------------------------------------------- residency ordering

    use crate::memory::batch_holder::MemEnv;
    use crate::memory::BatchHolder;
    use crate::types::{Column, RecordBatch};

    fn batch(rows: usize) -> RecordBatch {
        RecordBatch::new(vec![Column::i64("k", vec![3; rows])]).unwrap()
    }

    /// A holder with one device-resident batch.
    fn device_holder(env: &MemEnv) -> BatchHolder {
        let h = BatchHolder::new("dev", env.clone());
        h.push_batch(batch(200)).unwrap();
        h
    }

    /// A holder whose only batch sits on disk.
    fn spilled_holder(env: &MemEnv) -> BatchHolder {
        let h = BatchHolder::new("spill", env.clone());
        h.push_batch_host(batch(200)).unwrap();
        h.spill_host_one().unwrap();
        assert_eq!(h.residency().spilled_frac(), 1.0);
        h
    }

    fn bonus() -> ResidencyBonus {
        ResidencyBonus { device_bonus: 50, spilled_penalty: 200, rerank_batch: 8 }
    }

    #[test]
    fn zeroed_bonus_table_is_plain_priority_fifo() {
        // Acceptance: with the table zeroed, pop order matches the
        // pre-residency queue even for tasks that declare inputs.
        let env = MemEnv::test(1 << 20);
        let dev = device_holder(&env);
        let spill = spilled_holder(&env);
        let zero = ResidencyBonus { device_bonus: 0, spilled_penalty: 0, rerank_batch: 8 };
        let q = TaskQueue::with_residency(zero, Arc::new(crate::metrics::Metrics::default()));
        q.submit(task(0, 10, |_| Ok(())).with_input(spill.clone()));
        q.submit(task(1, 30, |_| Ok(())).with_input(dev.clone()));
        q.submit(task(2, 10, |_| Ok(())).with_input(dev));
        q.notify_residency_changed(spill.id()); // must be a no-op when off
        let order: Vec<usize> = std::iter::from_fn(|| q.try_pop().map(|t| t.op)).collect();
        assert_eq!(order, vec![1, 0, 2], "prio then FIFO, residency ignored");
    }

    #[test]
    fn spilled_input_never_outranks_device_resident_equal_base() {
        let env = MemEnv::test(1 << 20);
        let dev = device_holder(&env);
        let spill = spilled_holder(&env);
        let q = TaskQueue::with_residency(bonus(), Arc::new(crate::metrics::Metrics::default()));
        // spilled task submitted FIRST: FIFO alone would run it first
        q.submit(task(2, 1000, |_| Ok(())).with_input(spill));
        q.submit(task(1, 1000, |_| Ok(())).with_input(dev));
        assert_eq!(q.try_pop().unwrap().op, 1, "device-resident input wins");
        assert_eq!(q.try_pop().unwrap().op, 2);
    }

    #[test]
    fn aged_spilled_task_eventually_runs() {
        // Starvation bound: under a steady stream of fresh hot tasks,
        // the penalized task's rank decays toward the device bonus per
        // re-rank pass and wins on FIFO order once it ties.
        let env = MemEnv::test(1 << 20);
        let dev = device_holder(&env);
        let spill = spilled_holder(&env);
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let q = TaskQueue::with_residency(bonus(), metrics.clone());
        q.submit(task(2, 1000, |_| Ok(())).with_input(spill));
        let mut ran_spilled_at = None;
        for i in 0..16 {
            q.submit(task(1, 1000, |_| Ok(())).with_input(dev.clone()));
            // any completed movement triggers a pass; penalized entries
            // age even when their own holder did not move
            q.notify_residency_changed(dev.id());
            if q.try_pop().unwrap().op == 2 {
                ran_spilled_at = Some(i);
                break;
            }
        }
        // penalty 250 gap halves per pass: ties the bonus by pass 8
        let at = ran_spilled_at.expect("spilled task starved");
        assert!(at <= 9, "took {at} rounds");
        assert!(metrics.gauge_value("sched.residency_rerank_total") > 0);
    }

    #[test]
    fn rerank_batch_caps_rescoring_per_pass() {
        let env = MemEnv::test(1 << 20);
        let dev = device_holder(&env);
        let capped = ResidencyBonus { device_bonus: 50, spilled_penalty: 200, rerank_batch: 1 };
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let q = TaskQueue::with_residency(capped, metrics.clone());
        for op in 0..3 {
            q.submit(task(op, 100, |_| Ok(())).with_input(dev.clone()));
        }
        q.notify_residency_changed(dev.id());
        let _ = q.try_pop().unwrap();
        assert_eq!(
            metrics.gauge_value("sched.residency_rerank_total"),
            1,
            "one rescoring per pass at batch size 1"
        );
        // the deferred remainder is processed by the next pop
        let _ = q.try_pop().unwrap();
        assert!(metrics.gauge_value("sched.residency_rerank_total") >= 2);
    }

    #[test]
    fn worsened_inputs_reset_rerank_age() {
        // Soundness gap #1: a task whose penalty decayed while queued
        // must NOT keep that decay credit after its inputs move and
        // land cold again — the spilled penalty re-binds at age 0.
        let env = MemEnv::test(1 << 20);
        let dev = device_holder(&env);
        let h = BatchHolder::new("moving", env.clone());
        h.push_batch_host(batch(200)).unwrap();
        h.spill_host_one().unwrap(); // starts spilled: penalized
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let q = TaskQueue::with_residency(bonus(), metrics.clone());
        q.submit(task(7, 1000, |_| Ok(())).with_input(h.clone())); // rank 800

        // decay the penalty to the device bonus under decoy passes
        for _ in 0..10 {
            q.submit(task(0, 2000, |_| Ok(()))); // decoy always outranks
            q.notify_residency_changed(dev.id());
            assert_eq!(q.try_pop().unwrap().op, 0);
        }
        // the holder's data comes back hot...
        assert!(h.promote_one().unwrap());
        q.notify_residency_changed(h.id());
        q.submit(task(0, 2000, |_| Ok(())));
        assert_eq!(q.try_pop().unwrap().op, 0);
        // ...and spills again: the decayed rank must collapse back to
        // the penalized truth, not ride its earned age
        assert!(h.demote_one(crate::memory::Tier::Host).unwrap() > 0);
        assert_eq!(h.residency().spilled_frac(), 1.0);
        q.notify_residency_changed(h.id());
        q.submit(task(0, 2000, |_| Ok(())));
        assert_eq!(q.try_pop().unwrap().op, 0);

        // equal-base hot task submitted AFTER the spilled one: without
        // the age reset the spilled task ties at base+bonus and wins on
        // FIFO; with it, the hot task runs first
        q.submit(task(1, 1000, |_| Ok(())).with_input(dev.clone()));
        assert_eq!(
            q.try_pop().unwrap().op,
            1,
            "re-spilled inputs must penalize again (age reset)"
        );
        assert_eq!(q.try_pop().unwrap().op, 7);
    }

    #[test]
    fn capped_rerank_cursor_round_robins_by_seq() {
        // Soundness gap #2: with rerank_batch = 1, consecutive passes
        // must serve *different* relevant entries in submission order —
        // the resume point is a stable seq, not an index into the
        // transient heap permutation.
        let env = MemEnv::test(1 << 20);
        let spill = spilled_holder(&env);
        let capped = ResidencyBonus { device_bonus: 50, spilled_penalty: 200, rerank_batch: 1 };
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let q = TaskQueue::with_residency(capped, metrics.clone());
        // X then Y, both penalized at rank 800
        q.submit(task(10, 1000, |_| Ok(())).with_input(spill.clone())); // X
        q.submit(task(11, 1000, |_| Ok(())).with_input(spill.clone())); // Y
        // two capped passes driven by decoy pops
        for _ in 0..2 {
            q.submit(task(0, 2000, |_| Ok(())));
            q.notify_residency_changed(spill.id());
            assert_eq!(q.try_pop().unwrap().op, 0);
        }
        assert_eq!(
            metrics.gauge_value("sched.residency_rerank_total"),
            2,
            "one rescoring per capped pass"
        );
        // each pass aged a DIFFERENT entry exactly once: both now rank
        // at age-1 (925) and beat a 900 probe; a cursor that re-served
        // X twice would leave Y at 800, below the probe
        q.submit(task(1, 900, |_| Ok(())));
        let order: Vec<usize> =
            std::iter::from_fn(|| q.try_pop().map(|t| t.op)).collect();
        assert_eq!(order, vec![10, 11, 1], "round-robin aging by seq");
    }

    #[test]
    fn residency_bonus_score_bounds() {
        let b = bonus();
        let hot = crate::memory::ResidencySnapshot { device_bytes: 100, ..Default::default() };
        let cold = crate::memory::ResidencySnapshot { spilled_bytes: 100, ..Default::default() };
        assert_eq!(b.score(&hot, 0), 50);
        assert_eq!(b.score(&hot, 7), 50, "hot score is age-invariant");
        assert_eq!(b.score(&cold, 0), -200);
        // decays monotonically toward (and never past) the device bonus
        let mut last = -200;
        for age in 1..12 {
            let s = b.score(&cold, age);
            assert!(s >= last && s <= 50, "age {age}: {s}");
            last = s;
        }
        assert_eq!(last, 50);
        // empty inputs are neutral-hot (nothing can stall)
        assert_eq!(b.score(&crate::memory::ResidencySnapshot::default(), 0), 50);
    }

    #[test]
    fn quiescent_requires_empty_and_idle() {
        let q = TaskQueue::new();
        assert!(q.quiescent());
        q.submit(task(0, 0, |_| Ok(())));
        assert!(!q.quiescent());
        let t = q.pop(Duration::from_millis(10)).unwrap();
        assert!(!q.quiescent(), "in-flight task counts");
        let ctx = WorkerCtx::test();
        (t.run)(&ctx).unwrap();
        q.task_done();
        assert!(q.quiescent());
    }
}
