//! The event half of the Data-Movement plane (§3.3.2/§3.3.3).
//!
//! A [`PressureEvent`] is a `Condvar`-backed latch the memory tiers
//! signal the instant something movement-worthy happens:
//!
//! * [`crate::memory::DeviceArena`] raises **device** pressure when an
//!   allocation crosses the spill watermark or fails outright;
//! * [`crate::memory::PinnedPool`] raises **host** pressure when the
//!   fixed-size buffer pool runs dry;
//! * [`crate::memory::MemoryGovernor`] raises **device** pressure when
//!   a reservation cannot be granted;
//! * [`crate::executors::compute::TaskQueue`] marks the **queue** dirty
//!   when a task with pre-loadable I/O is submitted.
//!
//! The Data-Movement executor parks on [`PressureEvent::wait`] and
//! reacts in microseconds — replacing the seed's 5 ms utilization
//! polling loop. Signals are *accumulated* (needs add up, queue
//! dirtiness is sticky) so a burst of raises between two waits is never
//! lost, and `wait` drains the accumulated state atomically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::sync::{ranks, OrderedCondvar, OrderedMutex};

/// Accumulated, undelivered pressure.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PressureSnapshot {
    /// Bytes wanted free on the device tier (watermark overage and/or
    /// failed allocations/reservations since the last wait).
    pub device_need: usize,
    /// Bytes wanted free on the host (pinned) tier.
    pub host_need: usize,
    /// The compute queue gained tasks with pre-loadable inputs.
    pub queue_dirty: bool,
}

impl PressureSnapshot {
    pub fn is_empty(&self) -> bool {
        self.device_need == 0 && self.host_need == 0 && !self.queue_dirty
    }
}

#[derive(Default)]
struct State {
    pending: PressureSnapshot,
}

/// Shared condition-variable event connecting the memory tiers to the
/// Data-Movement executor.
pub struct PressureEvent {
    state: OrderedMutex<State>,
    cv: OrderedCondvar,
    raises: AtomicU64,
    /// Device/host raises only (not queue dirtiness): the monotonic
    /// *memory-pressure epoch*. Buffering producers — the coalescing
    /// exchange's per-destination shuffle builders — compare it against
    /// the epoch they last observed and flush early when it advanced,
    /// so buffered state drains instead of deepening a spill cycle.
    memory_raises: AtomicU64,
}

impl Default for PressureEvent {
    fn default() -> Self {
        PressureEvent {
            state: OrderedMutex::new(
                ranks::PRESSURE_STATE,
                "pressure.state",
                State::default(),
            ),
            cv: OrderedCondvar::new(),
            raises: AtomicU64::new(0),
            memory_raises: AtomicU64::new(0),
        }
    }
}

impl PressureEvent {
    pub fn new() -> Arc<PressureEvent> {
        Arc::new(PressureEvent::default())
    }

    /// Lifetime signal count (tests use this to prove event delivery).
    pub fn raise_count(&self) -> u64 {
        self.raises.load(Ordering::Relaxed)
    }

    /// Monotonic count of *memory* raises (device + host; queue
    /// dirtiness excluded). An advance since a caller's last read means
    /// some tier asked for bytes back in the interim.
    pub fn memory_raise_count(&self) -> u64 {
        self.memory_raises.load(Ordering::Relaxed)
    }

    /// Signal device-tier pressure: `bytes` should be freed.
    pub fn raise_device(&self, bytes: usize) {
        let mut s = self.state.lock();
        s.pending.device_need = s.pending.device_need.saturating_add(bytes);
        self.raises.fetch_add(1, Ordering::Relaxed);
        self.memory_raises.fetch_add(1, Ordering::Relaxed);
        self.cv.notify_all(&s);
    }

    /// Signal host-tier (pinned pool) pressure.
    pub fn raise_host(&self, bytes: usize) {
        let mut s = self.state.lock();
        s.pending.host_need = s.pending.host_need.saturating_add(bytes);
        self.raises.fetch_add(1, Ordering::Relaxed);
        self.memory_raises.fetch_add(1, Ordering::Relaxed);
        self.cv.notify_all(&s);
    }

    /// Mark the compute queue dirty (new pre-loadable work).
    pub fn mark_queue(&self) {
        let mut s = self.state.lock();
        s.pending.queue_dirty = true;
        self.raises.fetch_add(1, Ordering::Relaxed);
        self.cv.notify_all(&s);
    }

    /// Drain pending pressure without blocking.
    pub fn take(&self) -> PressureSnapshot {
        std::mem::take(&mut self.state.lock().pending)
    }

    /// Park until pressure arrives (or `timeout`, as a safety sweep for
    /// missed edges). Returns the drained snapshot; empty on timeout.
    pub fn wait(&self, timeout: Duration) -> PressureSnapshot {
        let deadline = Instant::now() + timeout;
        let mut s = self.state.lock();
        loop {
            if !s.pending.is_empty() {
                return std::mem::take(&mut s.pending);
            }
            let now = Instant::now();
            if now >= deadline {
                return PressureSnapshot::default();
            }
            let (guard, _) = self.cv.wait_timeout(s, deadline - now);
            s = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raises_accumulate_and_drain() {
        let ev = PressureEvent::new();
        ev.raise_device(100);
        ev.raise_device(50);
        ev.raise_host(7);
        ev.mark_queue();
        let snap = ev.take();
        assert_eq!(snap.device_need, 150);
        assert_eq!(snap.host_need, 7);
        assert!(snap.queue_dirty);
        assert!(ev.take().is_empty(), "drained");
        assert_eq!(ev.raise_count(), 4);
        assert_eq!(
            ev.memory_raise_count(),
            3,
            "queue dirtiness must not advance the memory epoch"
        );
    }

    #[test]
    fn wait_wakes_on_raise() {
        let ev = PressureEvent::new();
        let ev2 = ev.clone();
        let h = std::thread::spawn(move || ev2.wait(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        ev.raise_device(42);
        let snap = h.join().unwrap();
        assert_eq!(snap.device_need, 42);
    }

    #[test]
    fn wait_times_out_empty() {
        let ev = PressureEvent::new();
        let t0 = Instant::now();
        let snap = ev.wait(Duration::from_millis(30));
        assert!(snap.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn pending_signal_returns_immediately() {
        let ev = PressureEvent::new();
        ev.raise_host(9);
        let t0 = Instant::now();
        let snap = ev.wait(Duration::from_secs(5));
        assert_eq!(snap.host_need, 9);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
