//! Disk spill tier: segmented spill files with lock-free positional
//! I/O.
//!
//! The Batch Holder's last-resort target (§3.1: data "may be moved to a
//! larger memory (including storage) when resources are scarce"). One
//! `SpillStore` per worker. Writers reserve disjoint offsets with a
//! per-segment atomic and write with `pwrite`-style
//! [`FileExt::write_all_at`]; readers use [`FileExt::read_exact_at`].
//! The only lock on the data path is the *shared* side of the segment
//! RwLock (exclusive only during rotation), so concurrent demotions
//! and promotions never serialize on a shared file cursor (the seed
//! held one `Mutex<File>` across every `seek + read/write` pair).
//!
//! Segments rotate at a configurable size; a sealed segment whose
//! payloads have all been freed is deleted on the spot, so long-running
//! workers reclaim disk incrementally instead of only at drop.
//!
//! Long-lived *mostly*-dead segments (a few stubborn payloads pinning
//! hundreds of megabytes of dead file) are handled by
//! [`SpillStore::compact`]: live extents are copied forward into the
//! current segment, a remap entry redirects the old slot (holders keep
//! their `SpillSlot` by value — every read/free resolves through the
//! remap first), and the old file is deleted. Compaction runs under the
//! segments write lock, so in-flight writers and readers (who hold the
//! read side across their positional I/O resolution) are excluded.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::memory::pinned::{PinnedPool, PinnedSlab, SlabWriter};
use crate::{Error, Result};

/// Default rotation size (kept modest: per-query spill files, §4.2).
pub const DEFAULT_SEGMENT_BYTES: u64 = 256 << 20;

/// Handle to one spilled payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpillSlot {
    /// Which segment file holds the payload.
    pub segment: u32,
    pub offset: u64,
    pub len: u64,
}

/// One spill file. `write_off` is the atomic offset-reservation
/// cursor; `live_bytes` counts not-yet-freed payloads so fully dead
/// sealed segments can be reclaimed.
struct Segment {
    path: PathBuf,
    file: File,
    write_off: AtomicU64,
    live_bytes: AtomicU64,
    reclaimed: AtomicBool,
    /// Set when a write into this segment failed: the segment is sealed
    /// against *new* writes (the next writer rotates past it) while its
    /// already-landed payloads stay readable. See FAULTS.md.
    poisoned: AtomicBool,
    /// Live payload extents (`offset → len`) — what compaction copies
    /// forward. Inserted on write, removed on free/move.
    slots: Mutex<HashMap<u64, u64>>,
}

/// Segmented spill-file manager.
pub struct SpillStore {
    dir: PathBuf,
    worker_id: usize,
    segment_bytes: u64,
    /// Append-only: slot indices stay valid after rotation; reclaimed
    /// segments keep their entry (file deleted, flag set).
    segments: RwLock<Vec<Arc<Segment>>>,
    /// Where a compacted payload went: `(old segment, old offset)` →
    /// its new slot. Chains (a payload moved twice) are followed by
    /// [`SpillStore::resolve_locked`]; a freed slot drops its chain.
    remap: RwLock<HashMap<(u32, u64), SpillSlot>>,
    live_bytes: AtomicU64,
    spill_ops: AtomicU64,
    reload_ops: AtomicU64,
    rotations: AtomicU64,
    compacted: AtomicU64,
    write_failover: AtomicU64,
}

impl SpillStore {
    /// Create (or truncate) the spill store at `dir/worker-<id>.*.spill`
    /// with the default segment size.
    pub fn new(dir: impl Into<PathBuf>, worker_id: usize) -> Result<Self> {
        Self::with_segment_bytes(dir, worker_id, DEFAULT_SEGMENT_BYTES)
    }

    /// Create with an explicit rotation size (config knob
    /// `spill_segment_bytes`). A payload larger than the segment size
    /// still fits: it gets a fresh segment to itself.
    pub fn with_segment_bytes(
        dir: impl Into<PathBuf>,
        worker_id: usize,
        segment_bytes: u64,
    ) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let first = Self::open_segment(&dir, worker_id, 0)?;
        Ok(SpillStore {
            dir,
            worker_id,
            segment_bytes: segment_bytes.max(1),
            segments: RwLock::new(vec![Arc::new(first)]),
            remap: RwLock::new(HashMap::new()),
            live_bytes: AtomicU64::new(0),
            spill_ops: AtomicU64::new(0),
            reload_ops: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
            compacted: AtomicU64::new(0),
            write_failover: AtomicU64::new(0),
        })
    }

    /// A store rooted in a fresh temp directory (tests, examples).
    pub fn temp(tag: &str) -> Result<Self> {
        Self::temp_with(tag, DEFAULT_SEGMENT_BYTES)
    }

    /// Temp store with an explicit segment size.
    pub fn temp_with(tag: &str, segment_bytes: u64) -> Result<Self> {
        let dir = std::env::temp_dir().join(format!(
            "theseus-spill-{tag}-{}-{}",
            std::process::id(),
            self::unique()
        ));
        SpillStore::with_segment_bytes(dir, 0, segment_bytes)
    }

    fn open_segment(dir: &Path, worker_id: usize, idx: usize) -> Result<Segment> {
        let path = dir.join(format!("worker-{worker_id}.{idx}.spill"));
        let file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        Ok(Segment {
            path,
            file,
            write_off: AtomicU64::new(0),
            live_bytes: AtomicU64::new(0),
            reclaimed: AtomicBool::new(false),
            poisoned: AtomicBool::new(false),
            slots: Mutex::new(HashMap::new()),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Bytes currently spilled and not yet freed.
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes.load(Ordering::Relaxed)
    }

    pub fn spill_ops(&self) -> u64 {
        self.spill_ops.load(Ordering::Relaxed)
    }

    pub fn reload_ops(&self) -> u64 {
        self.reload_ops.load(Ordering::Relaxed)
    }

    /// Segments ever opened (reclaimed ones included).
    pub fn segment_count(&self) -> usize {
        self.segments.read().unwrap().len()
    }

    pub fn rotations(&self) -> u64 {
        self.rotations.load(Ordering::Relaxed)
    }

    /// Lifetime bytes copied forward by [`SpillStore::compact`].
    pub fn compacted_bytes(&self) -> u64 {
        self.compacted.load(Ordering::Relaxed)
    }

    /// Times a failed segment write was retried into a fresh segment
    /// (the old one sealed poisoned). Published as
    /// `spill.write_failover_total`.
    pub fn write_failover_total(&self) -> u64 {
        self.write_failover.load(Ordering::Relaxed)
    }

    /// Rotate if `observed_last` is still the last segment (another
    /// writer may have rotated already). Taking the write lock also
    /// waits out in-flight writers (which hold the read lock across
    /// their `pwrite`), so a sealed segment provably has no pending
    /// writes — the invariant `free` relies on to reclaim safely.
    fn rotate(&self, observed_last: usize) -> Result<()> {
        let mut segs = self.segments.write().unwrap();
        if segs.len() == observed_last + 1 {
            let seg = Self::open_segment(&self.dir, self.worker_id, segs.len())?;
            segs.push(Arc::new(seg));
            self.rotations.fetch_add(1, Ordering::Relaxed);
            // The just-sealed segment may already be fully dead (every
            // payload written and freed while it was current): reclaim
            // it here, or it would leak until drop.
            let sealed = &segs[observed_last];
            if sealed.live_bytes.load(Ordering::Acquire) == 0
                && !sealed.reclaimed.swap(true, Ordering::AcqRel)
            {
                let _ = std::fs::remove_file(&sealed.path);
            }
        }
        Ok(())
    }

    /// Append a payload; returns its slot. Writers share the segments
    /// read-lock (no serialization among themselves — offset
    /// reservation is a `fetch_add`, the write positional); holding it
    /// across the `pwrite` means rotation (the only path that seals a
    /// segment) cannot complete mid-write, so a write can never land
    /// in a segment that `free` is concurrently reclaiming.
    pub fn write(&self, data: &[u8]) -> Result<SpillSlot> {
        self.write_vectored(&[data])
    }

    /// Append a payload presented as vectored parts (a codec prelude
    /// plus a pinned slab's buffers): one offset reservation, one
    /// positional `write_all_at` per part — the slab is never
    /// reassembled into a heap `Vec` on the way to disk.
    pub fn write_vectored(&self, parts: &[&[u8]]) -> Result<SpillSlot> {
        let len: u64 = parts.iter().map(|p| p.len() as u64).sum();
        let mut failovers = 0u32;
        loop {
            let observed = {
                let segs = self.segments.read().unwrap();
                let idx = segs.len() - 1;
                let seg = &segs[idx];
                if seg.poisoned.load(Ordering::Acquire) {
                    // A prior write failed here: rotate past it without
                    // reserving (existing payloads stay readable).
                    idx
                } else {
                    let offset = seg.write_off.fetch_add(len, Ordering::AcqRel);
                    // In-budget, or an oversized payload opening a fresh
                    // segment (offset 0 always accepts).
                    if offset == 0 || offset + len <= self.segment_bytes {
                        // Bookkeeping happens only after every byte has
                        // landed, so a failed attempt leaves no live
                        // state behind — just an abandoned reservation.
                        let attempt = (|| -> Result<()> {
                            crate::fault::check(crate::fault::FaultSite::SpillWrite)?;
                            let mut at = offset;
                            for p in parts {
                                seg.file.write_all_at(p, at)?;
                                at += p.len() as u64;
                            }
                            Ok(())
                        })();
                        match attempt {
                            Ok(()) => {
                                seg.live_bytes.fetch_add(len, Ordering::AcqRel);
                                seg.slots.lock().unwrap().insert(offset, len);
                                self.live_bytes.fetch_add(len, Ordering::Relaxed);
                                self.spill_ops.fetch_add(1, Ordering::Relaxed);
                                return Ok(SpillSlot {
                                    segment: idx as u32,
                                    offset,
                                    len,
                                });
                            }
                            Err(e) => {
                                // Failover: seal the segment against new
                                // writes and retry the payload on a fresh
                                // one. Bounded — a persistently failing
                                // disk propagates after a few attempts.
                                seg.poisoned.store(true, Ordering::Release);
                                self.write_failover.fetch_add(1, Ordering::Relaxed);
                                failovers += 1;
                                if failovers > 3 {
                                    return Err(e);
                                }
                                log::warn!(
                                    "spill write failover #{failovers}: segment {idx} poisoned: {e}"
                                );
                                idx
                            }
                        }
                    } else {
                        // Segment full: the reserved range is abandoned
                        // (the file is never extended there); retry on a
                        // fresh segment, rotating outside the read lock.
                        idx
                    }
                }
            };
            self.rotate(observed)?;
        }
    }

    /// Follow the compaction remap chain. Callers must hold (at least)
    /// the segments read lock so compaction cannot move the resolved
    /// payload between resolution and use.
    fn resolve_locked(&self, slot: SpillSlot) -> SpillSlot {
        let remap = self.remap.read().unwrap();
        let mut cur = slot;
        while let Some(next) = remap.get(&(cur.segment, cur.offset)) {
            cur = *next;
        }
        cur
    }

    /// The live segment behind a slot (post-remap), with reclaim/bounds
    /// checks. Returns the resolved slot — file offsets must come from
    /// it, not from the caller's (possibly pre-compaction) handle.
    fn checked_segment(&self, slot: SpillSlot) -> Result<(Arc<Segment>, SpillSlot)> {
        crate::fault::check(crate::fault::FaultSite::SpillRead)?;
        let segs = self.segments.read().unwrap();
        let resolved = self.resolve_locked(slot);
        let seg = segs
            .get(resolved.segment as usize)
            .cloned()
            .ok_or_else(|| {
                Error::internal(format!("spill slot {slot:?}: no such segment"))
            })?;
        if seg.reclaimed.load(Ordering::Acquire) {
            return Err(Error::internal(format!(
                "spill slot {slot:?} read after segment reclaim"
            )));
        }
        let end = seg.write_off.load(Ordering::Acquire);
        if resolved.offset + resolved.len > end {
            return Err(Error::internal(format!(
                "spill slot {resolved:?} beyond write offset {end}"
            )));
        }
        Ok((seg, resolved))
    }

    /// Read a slot back (positional; concurrent with writers).
    pub fn read(&self, slot: SpillSlot) -> Result<Vec<u8>> {
        let (seg, slot) = self.checked_segment(slot)?;
        let mut buf = vec![0u8; slot.len as usize];
        seg.file.read_exact_at(&mut buf, slot.offset)?;
        self.reload_ops.fetch_add(1, Ordering::Relaxed);
        Ok(buf)
    }

    /// Peek `len` bytes at `skip` within a slot (codec-prelude sniffing
    /// on the promotion path; not counted as a reload).
    pub fn read_at(&self, slot: SpillSlot, skip: u64, len: usize) -> Result<Vec<u8>> {
        if skip + len as u64 > slot.len {
            return Err(Error::internal(format!(
                "spill peek {skip}+{len} beyond slot {slot:?}"
            )));
        }
        let (seg, slot) = self.checked_segment(slot)?;
        let mut buf = vec![0u8; len];
        seg.file.read_exact_at(&mut buf, slot.offset + skip)?;
        Ok(buf)
    }

    /// Reload a slot's bytes (past the first `skip`) straight into
    /// pinned pool buffers — the spill-promotion path's single bounce.
    /// Fails with `PinnedExhausted` (before touching the file) when the
    /// pool lacks room; the caller falls back to [`SpillStore::read`].
    pub fn read_into_slab(
        &self,
        slot: SpillSlot,
        skip: u64,
        pool: &PinnedPool,
    ) -> Result<PinnedSlab> {
        if skip > slot.len {
            return Err(Error::internal(format!(
                "spill skip {skip} beyond slot {slot:?}"
            )));
        }
        let n = (slot.len - skip) as usize;
        let mut w = SlabWriter::with_capacity(pool, n)?;
        let (seg, slot) = self.checked_segment(slot)?;
        let base = slot.offset + skip;
        w.fill_positional(n, |off, buf| seg.file.read_exact_at(buf, base + off))?;
        self.reload_ops.fetch_add(1, Ordering::Relaxed);
        Ok(w.finish())
    }

    /// Mark a slot dead. A sealed segment whose last live payload is
    /// freed has its file deleted immediately; a segment that dies
    /// while still current is reclaimed by the rotation that seals it.
    pub fn free(&self, slot: SpillSlot) {
        self.live_bytes.fetch_sub(slot.len, Ordering::Relaxed);
        // Resolve + decrement under the read lock: compaction and
        // rotation (write lock) then observe either the pre-free
        // liveness (and this path reclaims) or the post-free zero (and
        // they reclaim) — never a gap where both skip.
        let (seg, sealed, before, resolved) = {
            let segs = self.segments.read().unwrap();
            let resolved = self.resolve_locked(slot);
            match segs.get(resolved.segment as usize) {
                Some(s) => {
                    s.slots.lock().unwrap().remove(&resolved.offset);
                    (
                        s.clone(),
                        (resolved.segment as usize) < segs.len() - 1,
                        s.live_bytes.fetch_sub(resolved.len, Ordering::AcqRel),
                        resolved,
                    )
                }
                None => return,
            }
        };
        // the chain is dead with its payload — stop the remap growing
        if resolved != slot {
            let mut remap = self.remap.write().unwrap();
            let mut k = (slot.segment, slot.offset);
            while let Some(next) = remap.remove(&k) {
                k = (next.segment, next.offset);
            }
        }
        if sealed
            && before == resolved.len
            && !seg.reclaimed.swap(true, Ordering::AcqRel)
        {
            let _ = std::fs::remove_file(&seg.path);
        }
    }

    /// Compact sealed, mostly-dead segments: copy each live extent into
    /// the current segment, remap the old slots (holders resolve
    /// through the remap on every read/free), and delete the old file.
    /// A segment qualifies when less than half of its written bytes are
    /// still live. Runs under the segments write lock — in-flight
    /// writers hold the read side across their `pwrite`, so no write
    /// can land in a segment being retired. Returns bytes moved.
    pub fn compact(&self) -> Result<u64> {
        let segs = self.segments.write().unwrap();
        let last = segs.len() - 1;
        let target = segs[last].clone();
        let mut moved_total = 0u64;
        for (idx, seg) in segs.iter().enumerate().take(last) {
            if seg.reclaimed.load(Ordering::Acquire) {
                continue;
            }
            let live = seg.live_bytes.load(Ordering::Acquire);
            let written = seg.write_off.load(Ordering::Acquire);
            if live == 0 {
                // fully dead: plain reclaim, nothing to copy
                if !seg.reclaimed.swap(true, Ordering::AcqRel) {
                    let _ = std::fs::remove_file(&seg.path);
                }
                continue;
            }
            if live * 2 > written {
                continue; // mostly live: copying would churn, not save
            }
            let extents: Vec<(u64, u64)> = {
                let slots = seg.slots.lock().unwrap();
                slots.iter().map(|(&o, &l)| (o, l)).collect()
            };
            let mut remap = self.remap.write().unwrap();
            for (off, len) in extents {
                let mut buf = vec![0u8; len as usize];
                seg.file.read_exact_at(&mut buf, off)?;
                let dst = target.write_off.fetch_add(len, Ordering::AcqRel);
                target.file.write_all_at(&buf, dst)?;
                target.live_bytes.fetch_add(len, Ordering::AcqRel);
                target.slots.lock().unwrap().insert(dst, len);
                remap.insert(
                    (idx as u32, off),
                    SpillSlot { segment: last as u32, offset: dst, len },
                );
                moved_total += len;
            }
            drop(remap);
            seg.slots.lock().unwrap().clear();
            seg.live_bytes.store(0, Ordering::Release);
            if !seg.reclaimed.swap(true, Ordering::AcqRel) {
                let _ = std::fs::remove_file(&seg.path);
            }
        }
        self.compacted.fetch_add(moved_total, Ordering::Relaxed);
        Ok(moved_total)
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        for seg in self.segments.get_mut().unwrap().iter() {
            if !seg.reclaimed.load(Ordering::Relaxed) {
                let _ = std::fs::remove_file(&seg.path);
            }
        }
        let _ = std::fs::remove_dir(&self.dir); // only removes if empty
    }
}

fn unique() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let s = SpillStore::temp("rt").unwrap();
        let a = s.write(b"hello").unwrap();
        let b = s.write(b"theseus spill").unwrap();
        assert_eq!(s.read(a).unwrap(), b"hello");
        assert_eq!(s.read(b).unwrap(), b"theseus spill");
        assert_eq!(s.live_bytes(), 18);
        assert_eq!(s.spill_ops(), 2);
    }

    #[test]
    fn free_reduces_live_bytes() {
        let s = SpillStore::temp("free").unwrap();
        let a = s.write(&[0u8; 100]).unwrap();
        let _b = s.write(&[0u8; 50]).unwrap();
        s.free(a);
        assert_eq!(s.live_bytes(), 50);
    }

    #[test]
    fn out_of_bounds_slot_rejected() {
        let s = SpillStore::temp("oob").unwrap();
        let _ = s.write(b"x").unwrap();
        let bad = SpillSlot { segment: 0, offset: 100, len: 10 };
        assert!(s.read(bad).is_err());
        let no_seg = SpillSlot { segment: 9, offset: 0, len: 1 };
        assert!(s.read(no_seg).is_err());
    }

    #[test]
    fn segments_rotate_and_roundtrip() {
        let s = SpillStore::temp_with("rot", 64).unwrap();
        let slots: Vec<_> = (0..10u8)
            .map(|i| {
                let payload = vec![i; 40];
                (s.write(&payload).unwrap(), payload)
            })
            .collect();
        assert!(s.segment_count() >= 5, "{} segments", s.segment_count());
        assert!(s.rotations() >= 4);
        for (slot, want) in &slots {
            assert_eq!(&s.read(*slot).unwrap(), want);
        }
    }

    #[test]
    fn oversized_payload_gets_own_segment() {
        let s = SpillStore::temp_with("big", 64).unwrap();
        let _pad = s.write(&[1u8; 40]).unwrap();
        let big = vec![7u8; 500]; // far beyond the 64-byte budget
        let slot = s.write(&big).unwrap();
        assert_eq!(slot.offset, 0, "oversized payload starts a segment");
        assert_eq!(s.read(slot).unwrap(), big);
    }

    #[test]
    fn dead_sealed_segment_is_reclaimed() {
        let s = SpillStore::temp_with("reclaim", 64).unwrap();
        let a = s.write(&[1u8; 50]).unwrap();
        let b = s.write(&[2u8; 50]).unwrap(); // rotates: `a` now sealed
        assert!(b.segment > a.segment);
        let seg0_path = {
            let segs = s.segments.read().unwrap();
            segs[a.segment as usize].path.clone()
        };
        assert!(seg0_path.exists());
        s.free(a);
        assert!(!seg0_path.exists(), "dead sealed segment deleted");
        // the live segment is untouched
        assert_eq!(s.read(b).unwrap(), vec![2u8; 50]);
        assert!(s.read(a).is_err(), "reclaimed slot rejected");
    }

    #[test]
    fn concurrent_writers_get_disjoint_slots() {
        let s = std::sync::Arc::new(SpillStore::temp_with("conc", 4096).unwrap());
        let hs: Vec<_> = (0..4u8)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    (0..25)
                        .map(|i| {
                            let payload = vec![t * 32 + i; (i as usize + 1) * 3];
                            (s.write(&payload).unwrap(), payload)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in hs {
            for (slot, want) in h.join().unwrap() {
                assert_eq!(s.read(slot).unwrap(), want);
            }
        }
    }

    #[test]
    fn concurrent_readers_and_writers_no_serialization_errors() {
        // Correctness side of the micro-bench claim: mixed positional
        // readers and writers over rotating segments stay coherent.
        let s = std::sync::Arc::new(SpillStore::temp_with("mixed", 1 << 14).unwrap());
        let hs: Vec<_> = (0..4u8)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    let mut held = Vec::new();
                    for i in 0..200u32 {
                        let payload =
                            vec![t.wrapping_mul(31).wrapping_add(i as u8); 128];
                        held.push((s.write(&payload).unwrap(), payload));
                        if i % 3 == 0 {
                            let (slot, want) = &held[held.len() / 2];
                            assert_eq!(&s.read(*slot).unwrap(), want);
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(s.spill_ops(), 800);
    }

    #[test]
    fn vectored_write_lands_parts_contiguously() {
        let s = SpillStore::temp("vec").unwrap();
        let slot = s
            .write_vectored(&[b"head", b"middle-part", b"tail"])
            .unwrap();
        assert_eq!(slot.len, 19);
        assert_eq!(s.read(slot).unwrap(), b"headmiddle-parttail");
        // peek within the slot
        assert_eq!(s.read_at(slot, 4, 6).unwrap(), b"middle");
        assert!(s.read_at(slot, 18, 5).is_err(), "peek beyond slot");
    }

    #[test]
    fn reload_into_slab_skips_prefix() {
        let pool = PinnedPool::new(16, 8).unwrap();
        let s = SpillStore::temp("slabload").unwrap();
        let payload: Vec<u8> = (0..100u8).collect();
        let slot = s.write(&payload).unwrap();
        // skip the first 9 bytes, land the rest in pinned buffers
        let slab = s.read_into_slab(slot, 9, &pool).unwrap();
        assert_eq!(slab.read(), &payload[9..]);
        assert!(slab.num_buffers() >= 6, "91 bytes over 16-byte buffers");
        drop(slab);
        assert_eq!(pool.free_buffers(), 8, "buffers returned");
        // a dry pool fails cleanly before touching the file
        let _hold: Vec<_> = (0..8).map(|_| pool.try_acquire().unwrap()).collect();
        assert!(matches!(
            s.read_into_slab(slot, 0, &pool),
            Err(Error::PinnedExhausted { .. })
        ));
    }

    #[test]
    fn compaction_moves_live_extents_and_retires_the_segment() {
        // 100-byte segments: 3 payloads of 40 — two fill seg 0, the
        // third rotates (leaving a 40-byte abandoned reservation, so
        // seg 0's written = 120 while its content is 80).
        let s = SpillStore::temp_with("compact", 100).unwrap();
        let a = s.write(&[1u8; 40]).unwrap();
        let b = s.write(&[2u8; 40]).unwrap();
        let c = s.write(&[3u8; 40]).unwrap();
        assert_eq!((a.segment, b.segment, c.segment), (0, 0, 1));
        // fully live (80 of 120 written): above half, kept as-is
        assert_eq!(s.compact().unwrap(), 0, "mostly-live segment kept");
        s.free(b); // 40/120 live: now qualifies
        let seg0_path = {
            let segs = s.segments.read().unwrap();
            segs[0].path.clone()
        };
        assert!(seg0_path.exists());
        let moved = s.compact().unwrap();
        assert_eq!(moved, 40, "only the live extent is copied");
        assert_eq!(s.compacted_bytes(), 40);
        assert!(!seg0_path.exists(), "mostly-dead segment retired");
        // the stale handle still resolves through the remap
        assert_eq!(s.read(a).unwrap(), vec![1u8; 40]);
        assert_eq!(s.read(c).unwrap(), vec![3u8; 40]);
        // freeing through the stale handle frees the moved payload
        let live_before = s.live_bytes();
        s.free(a);
        assert_eq!(s.live_bytes(), live_before - 40);
        assert!(s.remap.read().unwrap().is_empty(), "dead chain pruned");
    }

    #[test]
    fn compaction_chains_resolve_after_repeated_moves() {
        let s = SpillStore::temp_with("chain", 100).unwrap();
        let dead = s.write(&[0u8; 60]).unwrap();
        let live = s.write(&[8u8; 20]).unwrap(); // seg 0: 80 written
        let _r1 = s.write(&[1u8; 90]).unwrap(); // rotates to seg 1
        s.free(dead);
        assert_eq!(s.compact().unwrap(), 20, "live moves into seg 1");
        // now make seg 1 mostly dead too and move on to seg 2
        s.free(_r1);
        let _r2 = s.write(&[2u8; 90]).unwrap(); // rotates to seg 2
        assert_eq!(s.compact().unwrap(), 20, "live moves again");
        assert_eq!(s.read(live).unwrap(), vec![8u8; 20], "two-hop chain");
        assert_eq!(s.compacted_bytes(), 40);
        s.free(live);
        assert!(s.remap.read().unwrap().is_empty());
    }

    #[test]
    fn files_removed_on_drop() {
        let s = SpillStore::temp_with("drop", 32).unwrap();
        let _ = s.write(&[0u8; 30]).unwrap();
        let _ = s.write(&[0u8; 30]).unwrap();
        let dir = s.dir().to_path_buf();
        assert!(dir.exists());
        drop(s);
        assert!(!dir.exists());
    }
}
