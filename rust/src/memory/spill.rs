//! Disk spill tier: append-only spill files with slot-based reload.
//!
//! The Batch Holder's last-resort target (§3.1: data "may be moved to a
//! larger memory (including storage) when resources are scarce"). One
//! `SpillStore` per worker; writes append to a rotating file, reads are
//! positional, and freed slots are tracked so the file can be reclaimed
//! when fully dead.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::{Error, Result};

/// Handle to one spilled payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpillSlot {
    pub offset: u64,
    pub len: u64,
}

/// Append-only spill file manager.
pub struct SpillStore {
    path: PathBuf,
    file: Mutex<File>,
    write_off: AtomicU64,
    live_bytes: AtomicU64,
    spill_ops: AtomicU64,
    reload_ops: AtomicU64,
}

impl SpillStore {
    /// Create (or truncate) the spill file at `dir/worker-<id>.spill`.
    pub fn new(dir: impl Into<PathBuf>, worker_id: usize) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("worker-{worker_id}.spill"));
        let file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        Ok(SpillStore {
            path,
            file: Mutex::new(file),
            write_off: AtomicU64::new(0),
            live_bytes: AtomicU64::new(0),
            spill_ops: AtomicU64::new(0),
            reload_ops: AtomicU64::new(0),
        })
    }

    /// A store rooted in a fresh temp directory (tests, examples).
    pub fn temp(tag: &str) -> Result<Self> {
        let dir = std::env::temp_dir().join(format!(
            "theseus-spill-{tag}-{}-{}",
            std::process::id(),
            self::unique()
        ));
        SpillStore::new(dir, 0)
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// Bytes currently spilled and not yet freed.
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes.load(Ordering::Relaxed)
    }

    pub fn spill_ops(&self) -> u64 {
        self.spill_ops.load(Ordering::Relaxed)
    }

    pub fn reload_ops(&self) -> u64 {
        self.reload_ops.load(Ordering::Relaxed)
    }

    /// Append a payload; returns its slot.
    pub fn write(&self, data: &[u8]) -> Result<SpillSlot> {
        let mut f = self.file.lock().unwrap();
        let offset = self.write_off.fetch_add(data.len() as u64, Ordering::AcqRel);
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(data)?;
        self.live_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.spill_ops.fetch_add(1, Ordering::Relaxed);
        Ok(SpillSlot { offset, len: data.len() as u64 })
    }

    /// Read a slot back.
    pub fn read(&self, slot: SpillSlot) -> Result<Vec<u8>> {
        let mut f = self.file.lock().unwrap();
        let end = self.write_off.load(Ordering::Acquire);
        if slot.offset + slot.len > end {
            return Err(Error::internal(format!(
                "spill slot {:?} beyond write offset {end}",
                slot
            )));
        }
        f.seek(SeekFrom::Start(slot.offset))?;
        let mut buf = vec![0u8; slot.len as usize];
        f.read_exact(&mut buf)?;
        self.reload_ops.fetch_add(1, Ordering::Relaxed);
        Ok(buf)
    }

    /// Mark a slot dead (space is reclaimed when the store drops; a
    /// production engine would compact, which the paper does not
    /// describe either — spill files are query-lifetime).
    pub fn free(&self, slot: SpillSlot) {
        self.live_bytes.fetch_sub(slot.len, Ordering::Relaxed);
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
        if let Some(dir) = self.path.parent() {
            let _ = std::fs::remove_dir(dir); // only removes if empty
        }
    }
}

fn unique() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let s = SpillStore::temp("rt").unwrap();
        let a = s.write(b"hello").unwrap();
        let b = s.write(b"theseus spill").unwrap();
        assert_eq!(s.read(a).unwrap(), b"hello");
        assert_eq!(s.read(b).unwrap(), b"theseus spill");
        assert_eq!(s.live_bytes(), 18);
        assert_eq!(s.spill_ops(), 2);
    }

    #[test]
    fn free_reduces_live_bytes() {
        let s = SpillStore::temp("free").unwrap();
        let a = s.write(&[0u8; 100]).unwrap();
        let _b = s.write(&[0u8; 50]).unwrap();
        s.free(a);
        assert_eq!(s.live_bytes(), 50);
    }

    #[test]
    fn out_of_bounds_slot_rejected() {
        let s = SpillStore::temp("oob").unwrap();
        let _ = s.write(b"x").unwrap();
        let bad = SpillSlot { offset: 100, len: 10 };
        assert!(s.read(bad).is_err());
    }

    #[test]
    fn concurrent_writers_get_disjoint_slots() {
        let s = std::sync::Arc::new(SpillStore::temp("conc").unwrap());
        let hs: Vec<_> = (0..4u8)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    (0..25)
                        .map(|i| {
                            let payload = vec![t * 32 + i; (i as usize + 1) * 3];
                            (s.write(&payload).unwrap(), payload)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in hs {
            for (slot, want) in h.join().unwrap() {
                assert_eq!(s.read(slot).unwrap(), want);
            }
        }
    }

    #[test]
    fn file_removed_on_drop() {
        let s = SpillStore::temp("drop").unwrap();
        let p = s.path().to_path_buf();
        assert!(p.exists());
        drop(s);
        assert!(!p.exists());
    }
}
