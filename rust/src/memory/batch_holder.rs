//! Batch Holder (§3.1): "an abstraction of a data container that
//! guarantees that inputs can always be stored somewhere in the system,
//! even when the intended target memory is full. Its data may be moved
//! to a larger memory (including storage) when resources are scarce."
//!
//! Holders are the DAG's edges: operators push output batches in,
//! downstream operators (via the Compute Executor) pop them out, and the
//! Data-Movement Executor demotes their contents across tiers under
//! pressure. Unlike CUDA Unified Memory, the holder can move data to
//! *storage*, change its format (compress on spill), and explicitly
//! promote data back ahead of a kernel launch (the same executor's
//! Compute-Task Pre-loading, §3.3.3).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::memory::{
    DeviceAlloc, DeviceArena, PinnedPool, PinnedSlab, SlabSlice, SlabWriter, SpillStore,
    StagedBytes, Tier,
};
use crate::sim::Throttle;
use crate::storage::compression::{Codec, PRELUDE_LEN};
use crate::types::RecordBatch;
use crate::{Error, Result};

/// A device-resident batch: the payload plus its arena accounting.
pub struct DeviceBatch {
    pub batch: RecordBatch,
    _alloc: DeviceAlloc,
}

impl DeviceBatch {
    /// Account `batch` against the arena (fails with retryable OOM).
    pub fn new(arena: &DeviceArena, batch: RecordBatch) -> Result<DeviceBatch> {
        let alloc = arena.alloc(batch.byte_size())?;
        Ok(DeviceBatch { batch, _alloc: alloc })
    }

    pub fn rows(&self) -> usize {
        self.batch.rows()
    }

    pub fn byte_size(&self) -> usize {
        self.batch.byte_size()
    }
}

impl std::fmt::Debug for DeviceBatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DeviceBatch({} rows, {} bytes)", self.rows(), self.byte_size())
    }
}

/// Shared memory environment of one worker: the three tiers plus the
/// modeled links between them.
#[derive(Clone)]
pub struct MemEnv {
    pub arena: DeviceArena,
    /// `None` reproduces Fig-4 config A (no pinned pool: host copies pay
    /// the pageable penalty).
    pub pinned: Option<PinnedPool>,
    pub spill: Arc<SpillStore>,
    /// Host <-> device link (PCIe).
    pub pcie: Throttle,
    /// Host <-> disk link (local NVMe-ish).
    pub disk: Throttle,
    /// Extra PCIe time multiplier for pageable (non-pinned) copies.
    pub pageable_penalty: f64,
    /// Codec applied when demoting host -> disk.
    pub spill_codec: Codec,
    /// Worker-wide demotion count: every time data lands (or is moved)
    /// below its intended tier — OOM push fallbacks and Memory-Executor
    /// spills alike. This is the §4.2 "spilling" the benches report.
    pub demotions: Arc<std::sync::atomic::AtomicU64>,
}

impl MemEnv {
    /// Test environment: instant links, small arena, pinned pool on.
    pub fn test(device_capacity: usize) -> MemEnv {
        let ctx = crate::sim::SimContext::test();
        MemEnv {
            arena: DeviceArena::new(device_capacity),
            pinned: Some(PinnedPool::new(16 * 1024, 64).unwrap()),
            spill: Arc::new(SpillStore::temp("memenv").unwrap()),
            pcie: ctx.throttle(&ctx.profile.pcie),
            disk: ctx.throttle(&ctx.profile.storage),
            pageable_penalty: ctx.profile.pageable_penalty,
            spill_codec: Codec::None,
            demotions: Arc::new(std::sync::atomic::AtomicU64::new(0)),
        }
    }

    pub fn demotions(&self) -> u64 {
        self.demotions.load(Ordering::Relaxed)
    }

    /// Charge a host<->device copy of `n` bytes, pinned or pageable.
    pub fn charge_pcie(&self, n: usize, pinned: bool) {
        if pinned {
            self.pcie.acquire(n);
        } else {
            // Pageable copies stage through an internal buffer at
            // reduced throughput (CUDA best-practices §10).
            self.pcie.acquire((n as f64 * self.pageable_penalty) as usize);
        }
    }
}

/// One stored batch at some tier.
enum Slot {
    Device(DeviceBatch),
    /// Encoded batch bytes in the pinned pool (a shared slab view: the
    /// network receive path hands payload slabs over without copying).
    HostPinned(SlabSlice),
    /// Encoded batch bytes in pageable host memory.
    HostPageable(Vec<u8>),
    /// Compressed encoded bytes on disk.
    Disk(crate::memory::spill::SpillSlot),
}

impl Slot {
    fn tier(&self) -> Tier {
        match self {
            Slot::Device(_) => Tier::Device,
            Slot::HostPinned(_) | Slot::HostPageable(_) => Tier::Host,
            Slot::Disk(_) => Tier::Disk,
        }
    }

    fn class(&self) -> ResidencyClass {
        match self {
            Slot::Device(_) => ResidencyClass::Device,
            Slot::HostPinned(_) => ResidencyClass::HostPinned,
            Slot::HostPageable(_) => ResidencyClass::HostHeap,
            Slot::Disk(_) => ResidencyClass::Spilled,
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Slot::Device(b) => b.byte_size(),
            Slot::HostPinned(s) => s.len(),
            Slot::HostPageable(v) => v.len(),
            Slot::Disk(s) => s.len as usize,
        }
    }
}

/// Where a holder's bytes live, at scheduler granularity — finer than
/// [`Tier`]: the host tier splits into pinned-pool and pageable-heap
/// bytes, which promote to device at very different speeds (§3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidencyClass {
    Device,
    HostPinned,
    HostHeap,
    Spilled,
}

const NUM_CLASSES: usize = 4;

fn class_idx(c: ResidencyClass) -> usize {
    match c {
        ResidencyClass::Device => 0,
        ResidencyClass::HostPinned => 1,
        ResidencyClass::HostHeap => 2,
        ResidencyClass::Spilled => 3,
    }
}

/// Cheap per-holder residency snapshot: byte totals per class, read
/// from the holder's atomic accounting (no slots lock, no clones). The
/// Compute Executor's residency-aware priority reads one of these per
/// task input (§3.3.1: priorities consider "the memory tier that the
/// input data resides in").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencySnapshot {
    pub device_bytes: usize,
    pub host_pinned_bytes: usize,
    pub host_heap_bytes: usize,
    pub spilled_bytes: usize,
}

impl ResidencySnapshot {
    pub fn total_bytes(&self) -> usize {
        self.device_bytes + self.host_pinned_bytes + self.host_heap_bytes + self.spilled_bytes
    }

    /// Fraction of bytes already on device (1.0 for an empty holder:
    /// nothing needs moving, so nothing can stall).
    pub fn device_frac(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 {
            1.0
        } else {
            self.device_bytes as f64 / total as f64
        }
    }

    /// Fraction of bytes that must come back from disk before a
    /// consumer runs at device speed.
    pub fn spilled_frac(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 {
            0.0
        } else {
            self.spilled_bytes as f64 / total as f64
        }
    }

    /// Accumulate another holder's snapshot (multi-input tasks).
    pub fn merge(&mut self, other: &ResidencySnapshot) {
        self.device_bytes += other.device_bytes;
        self.host_pinned_bytes += other.host_pinned_bytes;
        self.host_heap_bytes += other.host_heap_bytes;
        self.spilled_bytes += other.spilled_bytes;
    }
}

/// Per-tier occupancy snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HolderStats {
    pub device_batches: usize,
    pub device_bytes: usize,
    pub host_batches: usize,
    pub host_bytes: usize,
    pub disk_batches: usize,
    pub disk_bytes: usize,
}

impl HolderStats {
    pub fn total_batches(&self) -> usize {
        self.device_batches + self.host_batches + self.disk_batches
    }

    pub fn total_bytes(&self) -> usize {
        self.device_bytes + self.host_bytes + self.disk_bytes
    }
}

/// The holder itself. Cheaply cloneable; all clones share state.
#[derive(Clone)]
pub struct BatchHolder {
    inner: Arc<Inner>,
}

struct Inner {
    name: String,
    env: MemEnv,
    slots: Mutex<VecDeque<Slot>>,
    /// Per-residency-class occupancy kept in atomics so
    /// [`BatchHolder::stats`], [`BatchHolder::residency`], and the
    /// movement plane's victim scans never take the slots lock (the
    /// seed cloned every holder per monitor pass). Indexed by
    /// [`class_idx`]; tier-level views sum pinned + heap for host.
    class_batches: [AtomicU64; NUM_CLASSES],
    class_bytes: [AtomicU64; NUM_CLASSES],
    /// Upstream has promised no more pushes.
    finished: AtomicBool,
    /// Lifetime totals (exchange size estimation input, §3.2).
    pushed_batches: AtomicU64,
    pushed_bytes: AtomicU64,
    spill_demotions: AtomicU64,
    promotions: AtomicU64,
}

impl Inner {
    fn account_add(&self, class: ResidencyClass, bytes: usize) {
        let i = class_idx(class);
        self.class_batches[i].fetch_add(1, Ordering::Relaxed);
        self.class_bytes[i].fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn account_sub(&self, class: ResidencyClass, bytes: usize) {
        let i = class_idx(class);
        self.class_batches[i].fetch_sub(1, Ordering::Relaxed);
        self.class_bytes[i].fetch_sub(bytes as u64, Ordering::Relaxed);
    }
}

impl BatchHolder {
    pub fn new(name: impl Into<String>, env: MemEnv) -> Self {
        BatchHolder {
            inner: Arc::new(Inner {
                name: name.into(),
                env,
                slots: Mutex::new(VecDeque::new()),
                class_batches: Default::default(),
                class_bytes: Default::default(),
                finished: AtomicBool::new(false),
                pushed_batches: AtomicU64::new(0),
                pushed_bytes: AtomicU64::new(0),
                spill_demotions: AtomicU64::new(0),
                promotions: AtomicU64::new(0),
            }),
        }
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Stable identity of the shared holder state (clones agree) — the
    /// movement planner uses it to keep a holder out of the demotion
    /// and promotion lists in the same round.
    pub fn id(&self) -> usize {
        Arc::as_ptr(&self.inner) as usize
    }

    pub fn env(&self) -> &MemEnv {
        &self.inner.env
    }

    // ------------------------------------------------------------- push

    /// Store a device-resident batch. If the arena cannot hold it the
    /// batch is demoted straight to host (or disk) — the holder's
    /// guarantee that a push never fails for lack of the *intended*
    /// memory. Returns the tier actually used.
    pub fn push_device(&self, batch: DeviceBatch) -> Result<Tier> {
        self.note_push(batch.byte_size());
        self.store(Slot::Device(batch), true)
    }

    /// Store a batch that is *not* yet accounted on device: tries to
    /// account it (device preferred), else demotes to host — the
    /// holder's never-fail guarantee. Scan / receive path.
    pub fn push_batch(&self, batch: RecordBatch) -> Result<Tier> {
        self.note_push(batch.byte_size());
        match self.inner.env.arena.alloc(batch.byte_size()) {
            Ok(alloc) => {
                self.store(Slot::Device(DeviceBatch { batch, _alloc: alloc }), false)
            }
            Err(Error::DeviceOom { .. }) => {
                self.inner
                    .env
                    .demotions
                    .fetch_add(1, Ordering::Relaxed);
                let slot = self.host_slot(batch.encode())?;
                self.store(slot, false)
            }
            Err(e) => Err(e),
        }
    }

    /// Store encoded batch bytes directly at host tier (network receive,
    /// byte-range pre-load staging).
    pub fn push_encoded(&self, bytes: Vec<u8>) -> Result<Tier> {
        self.push_host_bytes(StagedBytes::Heap(bytes))
    }

    /// Store already-staged bytes at host tier. Slab-backed bytes (a
    /// received network payload, a re-queued exchange batch) become the
    /// host slot as-is — no copy, the pool buffers just change owner.
    pub fn push_host_bytes(&self, bytes: StagedBytes) -> Result<Tier> {
        self.note_push(bytes.len());
        let slot = match bytes {
            // Adopt the slab only as its sole owner. An Arc-shared view
            // (an in-proc broadcast delivers one slab to N holders)
            // would make per-holder host accounting exceed physical
            // pool usage, and demoting one holder's copy would "free"
            // bytes the siblings still pin — so shared views are
            // re-staged into independent memory instead.
            StagedBytes::Pinned(s) if s.is_exclusive() => Slot::HostPinned(s),
            StagedBytes::Pinned(s) => self.host_slot(s.to_vec())?,
            StagedBytes::Heap(v) => self.host_slot(v)?,
        };
        self.store(slot, false)
    }

    /// Store a batch preferring host tier (pre-load staging that should
    /// not consume device memory).
    pub fn push_batch_host(&self, batch: RecordBatch) -> Result<Tier> {
        self.push_encoded(batch.encode())
    }

    fn note_push(&self, bytes: usize) {
        self.inner.pushed_batches.fetch_add(1, Ordering::Relaxed);
        self.inner.pushed_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn store(&self, slot: Slot, charged: bool) -> Result<Tier> {
        let tier = slot.tier();
        let _ = charged;
        self.inner.account_add(slot.class(), slot.bytes());
        self.inner.slots.lock().unwrap().push_back(slot);
        Ok(tier)
    }

    /// Encode to a host slot: pinned pool first, pageable fallback.
    fn host_slot(&self, bytes: Vec<u8>) -> Result<Slot> {
        if let Some(pool) = &self.inner.env.pinned {
            if let Ok(slab) = PinnedSlab::write(pool, &bytes) {
                return Ok(Slot::HostPinned(SlabSlice::whole(slab)));
            }
        }
        Ok(Slot::HostPageable(bytes))
    }

    // -------------------------------------------------------------- pop

    /// Pop the next batch, materialized on device (the compute-task
    /// input path: "loading input batches from batch holders into GPU
    /// memory", §3.3.1). Returns `Ok(None)` when currently empty;
    /// a retryable OOM if the arena cannot take the batch.
    pub fn pop_device(&self) -> Result<Option<DeviceBatch>> {
        let slot = match self.inner.slots.lock().unwrap().pop_front() {
            Some(s) => s,
            None => return Ok(None),
        };
        self.inner.account_sub(slot.class(), slot.bytes());
        match self.materialize_device(slot) {
            Ok(db) => Ok(Some(db)),
            Err((Some(slot), e)) => {
                // Put it back at the front so order is preserved; the
                // compute executor treats the OOM as retryable.
                self.inner.account_add(slot.class(), slot.bytes());
                self.inner.slots.lock().unwrap().push_front(slot);
                Err(e)
            }
            Err((None, e)) => Err(e),
        }
    }

    /// Pop the next batch as encoded host bytes (network-send path; no
    /// device memory involved). Host-pinned slots hand their slab view
    /// over as-is, so the Network Executor can `write_vectored` the
    /// buffers onto the wire without reassembling them.
    pub fn pop_encoded(&self) -> Result<Option<StagedBytes>> {
        let slot = match self.inner.slots.lock().unwrap().pop_front() {
            Some(s) => s,
            None => return Ok(None),
        };
        self.inner.account_sub(slot.class(), slot.bytes());
        let env = &self.inner.env;
        Ok(Some(match slot {
            Slot::Device(db) => {
                let bytes = db.batch.encode();
                env.charge_pcie(bytes.len(), env.pinned.is_some());
                StagedBytes::Heap(bytes)
            }
            Slot::HostPinned(s) => StagedBytes::Pinned(s),
            Slot::HostPageable(v) => StagedBytes::Heap(v),
            Slot::Disk(s) => {
                let raw = env.spill.read(s)?;
                env.disk.acquire(raw.len());
                env.spill.free(s);
                StagedBytes::Heap(Codec::decompress(&raw)?)
            }
        }))
    }

    fn materialize_device(
        &self,
        slot: Slot,
    ) -> std::result::Result<DeviceBatch, (Option<Slot>, Error)> {
        let env = &self.inner.env;
        match slot {
            Slot::Device(db) => Ok(db),
            Slot::HostPinned(s) => {
                // device upload: decode from the slab view (contiguous
                // borrow when it fits one buffer)
                let batch = RecordBatch::decode(&s.contiguous()).map_err(|e| (None, e))?;
                match DeviceBatch::new(&env.arena, batch) {
                    Ok(db) => {
                        env.charge_pcie(s.len(), true);
                        self.inner.promotions.fetch_add(1, Ordering::Relaxed);
                        Ok(db)
                    }
                    Err(e) => Err((Some(Slot::HostPinned(s)), e)),
                }
            }
            Slot::HostPageable(v) => {
                let batch = RecordBatch::decode(&v).map_err(|e| (None, e))?;
                match DeviceBatch::new(&env.arena, batch) {
                    Ok(db) => {
                        env.charge_pcie(v.len(), false);
                        self.inner.promotions.fetch_add(1, Ordering::Relaxed);
                        Ok(db)
                    }
                    Err(e) => Err((Some(Slot::HostPageable(v)), e)),
                }
            }
            Slot::Disk(s) => {
                let raw = env.spill.read(s).map_err(|e| (Some(Slot::Disk(s)), e))?;
                env.disk.acquire(raw.len());
                let bytes = Codec::decompress(&raw).map_err(|e| (None, e))?;
                let batch = RecordBatch::decode(&bytes).map_err(|e| (None, e))?;
                match DeviceBatch::new(&env.arena, batch) {
                    Ok(db) => {
                        env.spill.free(s);
                        env.charge_pcie(bytes.len(), env.pinned.is_some());
                        self.inner.promotions.fetch_add(1, Ordering::Relaxed);
                        Ok(db)
                    }
                    Err(e) => Err((Some(Slot::Disk(s)), e)),
                }
            }
        }
    }

    // ------------------------------------------------------ spill/promote

    /// Tier-transition API used by the Data-Movement executor: demote
    /// the newest batch of `from` one tier down. Returns bytes freed at
    /// `from`, 0 if that tier is empty here (or has nowhere to go).
    pub fn demote_one(&self, from: Tier) -> Result<usize> {
        match from {
            Tier::Device => self.spill_one(),
            Tier::Host => self.spill_host_one(),
            Tier::Disk => Ok(0),
        }
    }

    /// Tier-transition API: promote the oldest disk batch to host.
    /// Returns true if something moved.
    pub fn promote_one(&self) -> Result<bool> {
        self.promote_one_to_host()
    }

    /// Demote the *newest* device-tier batch one tier (LIFO spill: the
    /// oldest batches are next to be consumed, so spilling from the back
    /// implements "avoid spilling data for which compute tasks are close
    /// to being executed", §3.3.2). Returns bytes freed on device, 0 if
    /// nothing to spill.
    pub fn spill_one(&self) -> Result<usize> {
        // Find the last device slot while holding the lock, take it out.
        let taken = {
            let mut slots = self.inner.slots.lock().unwrap();
            let idx = slots.iter().rposition(|s| s.tier() == Tier::Device);
            idx.map(|i| (i, slots.remove(i).unwrap()))
        };
        let (idx, slot) = match taken {
            Some(x) => x,
            None => return Ok(0),
        };
        let env = &self.inner.env;
        let db = match slot {
            Slot::Device(db) => db,
            _ => unreachable!(),
        };
        let freed = db.byte_size();
        self.inner.account_sub(ResidencyClass::Device, freed);
        let bytes = db.batch.encode();
        env.charge_pcie(bytes.len(), env.pinned.is_some());
        drop(db); // release arena accounting before storing host copy
        let new_slot = self.host_slot(bytes)?;
        self.inner.account_add(new_slot.class(), new_slot.bytes());
        {
            let mut slots = self.inner.slots.lock().unwrap();
            let at = idx.min(slots.len()); // deque may have shrunk concurrently
            slots.insert(at, new_slot);
        }
        self.inner.spill_demotions.fetch_add(1, Ordering::Relaxed);
        self.inner.env.demotions.fetch_add(1, Ordering::Relaxed);
        Ok(freed)
    }

    /// Demote the newest host-tier batch to disk (compressing with the
    /// env's spill codec). A pinned slot goes down via per-chunk
    /// positional writes straight from the slab — no reassembly copy;
    /// a real codec streams the chunks through the compressor instead.
    /// Returns host bytes freed.
    pub fn spill_host_one(&self) -> Result<usize> {
        let taken = {
            let mut slots = self.inner.slots.lock().unwrap();
            let idx = slots.iter().rposition(|s| s.tier() == Tier::Host);
            idx.map(|i| (i, slots.remove(i).unwrap()))
        };
        let (idx, slot) = match taken {
            Some(x) => x,
            None => return Ok(0),
        };
        let env = &self.inner.env;
        let (freed, disk_slot) = match slot {
            Slot::HostPinned(s) => {
                let freed = s.len();
                self.inner.account_sub(ResidencyClass::HostPinned, freed);
                let disk_slot = match env.spill_codec {
                    Codec::None => {
                        // direct: prelude + slab chunks, each written at
                        // its own offset
                        let prelude = Codec::None.prelude(s.len());
                        let chunks = s.chunks();
                        let mut parts: Vec<&[u8]> = Vec::with_capacity(1 + chunks.len());
                        parts.push(&prelude);
                        parts.extend_from_slice(&chunks);
                        env.disk.acquire(PRELUDE_LEN + s.len());
                        env.spill.write_vectored(&parts)?
                    }
                    codec => {
                        let compressed = codec.compress_chunks(&s.chunks());
                        env.disk.acquire(compressed.len());
                        env.spill.write(&compressed)?
                    }
                };
                (freed, disk_slot)
            }
            Slot::HostPageable(v) => {
                let freed = v.len();
                self.inner.account_sub(ResidencyClass::HostHeap, freed);
                let compressed = env.spill_codec.compress(&v);
                env.disk.acquire(compressed.len());
                (freed, env.spill.write(&compressed)?)
            }
            _ => unreachable!(),
        };
        self.inner.account_add(ResidencyClass::Spilled, disk_slot.len as usize);
        {
            let mut slots = self.inner.slots.lock().unwrap();
            let at = idx.min(slots.len());
            slots.insert(at, Slot::Disk(disk_slot));
        }
        self.inner.spill_demotions.fetch_add(1, Ordering::Relaxed);
        self.inner.env.demotions.fetch_add(1, Ordering::Relaxed);
        Ok(freed)
    }

    /// Promote the oldest non-device batch to host (the Data-Movement
    /// executor's Compute-Task Pre-loading stages disk data at host so
    /// the compute pop only pays the PCIe hop). Returns true if
    /// something moved.
    pub fn promote_one_to_host(&self) -> Result<bool> {
        let taken = {
            let mut slots = self.inner.slots.lock().unwrap();
            let idx = slots.iter().position(|s| s.tier() == Tier::Disk);
            idx.map(|i| (i, slots.remove(i).unwrap()))
        };
        let (idx, slot) = match taken {
            Some(x) => x,
            None => return Ok(false),
        };
        let s = match slot {
            Slot::Disk(s) => s,
            _ => unreachable!(),
        };
        self.inner.account_sub(ResidencyClass::Spilled, s.len as usize);
        let new_slot = self.reload_host_slot(s)?;
        self.inner.account_add(new_slot.class(), new_slot.bytes());
        {
            let mut slots = self.inner.slots.lock().unwrap();
            let at = idx.min(slots.len());
            slots.insert(at, new_slot);
        }
        self.inner.promotions.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// Reload a spilled payload into a host slot. Uncompressed spill
    /// (the common `spill_codec: None` case) is read from disk straight
    /// into pinned buffers — one positional read per buffer, no heap
    /// staging `Vec`; compressed spill is decompressed *into* a slab
    /// writer. Both fall back to pageable memory when the pool is dry.
    fn reload_host_slot(&self, s: crate::memory::spill::SpillSlot) -> Result<Slot> {
        let env = &self.inner.env;
        if let Some(pool) = &env.pinned {
            if s.len >= PRELUDE_LEN as u64 {
                let head = env.spill.read_at(s, 0, PRELUDE_LEN)?;
                if let Ok((codec, orig)) = Codec::parse_prelude(&head) {
                    if matches!(codec, Codec::None)
                        && orig as u64 == s.len - PRELUDE_LEN as u64
                    {
                        match env.spill.read_into_slab(s, PRELUDE_LEN as u64, pool) {
                            Ok(slab) => {
                                env.disk.acquire(s.len as usize);
                                env.spill.free(s);
                                return Ok(Slot::HostPinned(SlabSlice::whole(slab)));
                            }
                            Err(Error::PinnedExhausted { .. }) => {} // pageable fallback
                            Err(e) => return Err(e),
                        }
                    }
                }
            }
        }
        let raw = env.spill.read(s)?;
        env.disk.acquire(raw.len());
        env.spill.free(s);
        if let Some(pool) = &env.pinned {
            if let Ok((_, orig)) = Codec::parse_prelude(&raw) {
                match SlabWriter::with_capacity(pool, orig) {
                    Ok(mut w) => {
                        // disk bytes entering the pool: a real bounce
                        // copy, counted (Lz4Like now streams through
                        // its window here — no full heap Vec first)
                        let claimed = Codec::decompress_into(&raw, &mut w)?;
                        if w.len() != claimed {
                            return Err(Error::Format(format!(
                                "spill reload length mismatch: {} vs {claimed}",
                                w.len()
                            )));
                        }
                        return Ok(Slot::HostPinned(SlabSlice::whole(w.finish())));
                    }
                    // dry pool: pageable reload below, visibly
                    Err(Error::PinnedExhausted { .. }) => pool.note_codec_fallback(orig),
                    Err(e) => return Err(e),
                }
            }
        }
        let bytes = Codec::decompress(&raw)?;
        self.host_slot(bytes)
    }

    // ------------------------------------------------------------ state

    pub fn len(&self) -> usize {
        self.inner.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mark that no more batches will be pushed.
    pub fn finish(&self) {
        self.inner.finished.store(true, Ordering::Release);
    }

    pub fn is_finished(&self) -> bool {
        self.inner.finished.load(Ordering::Acquire)
    }

    /// Finished and drained: downstream operator can complete.
    pub fn is_exhausted(&self) -> bool {
        self.is_finished() && self.is_empty()
    }

    /// Lifetime pushed bytes (the Adaptive Exchange estimates total
    /// input from this after a few batches, §3.2).
    pub fn bytes_pushed(&self) -> u64 {
        self.inner.pushed_bytes.load(Ordering::Relaxed)
    }

    pub fn batches_pushed(&self) -> u64 {
        self.inner.pushed_batches.load(Ordering::Relaxed)
    }

    pub fn spill_demotions(&self) -> u64 {
        self.inner.spill_demotions.load(Ordering::Relaxed)
    }

    pub fn promotions(&self) -> u64 {
        self.inner.promotions.load(Ordering::Relaxed)
    }

    /// Per-tier occupancy, read from atomics — no slots lock, no
    /// cloning. This is the movement planner's victim-scan input, read
    /// once per registered holder on every pressure wake. The host tier
    /// sums the pinned and pageable residency classes.
    pub fn stats(&self) -> HolderStats {
        let b = &self.inner.class_batches;
        let y = &self.inner.class_bytes;
        HolderStats {
            device_batches: b[0].load(Ordering::Relaxed) as usize,
            device_bytes: y[0].load(Ordering::Relaxed) as usize,
            host_batches: (b[1].load(Ordering::Relaxed) + b[2].load(Ordering::Relaxed))
                as usize,
            host_bytes: (y[1].load(Ordering::Relaxed) + y[2].load(Ordering::Relaxed))
                as usize,
            disk_batches: b[3].load(Ordering::Relaxed) as usize,
            disk_bytes: y[3].load(Ordering::Relaxed) as usize,
        }
    }

    /// Residency snapshot at class granularity — the scheduler-facing
    /// view (same atomics as [`BatchHolder::stats`], host split into
    /// pinned and heap). Cheap enough to read per queued task.
    pub fn residency(&self) -> ResidencySnapshot {
        let y = &self.inner.class_bytes;
        ResidencySnapshot {
            device_bytes: y[0].load(Ordering::Relaxed) as usize,
            host_pinned_bytes: y[1].load(Ordering::Relaxed) as usize,
            host_heap_bytes: y[2].load(Ordering::Relaxed) as usize,
            spilled_bytes: y[3].load(Ordering::Relaxed) as usize,
        }
    }
}

impl std::fmt::Debug for BatchHolder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.stats();
        write!(
            f,
            "BatchHolder('{}', dev {}/{}B, host {}/{}B, disk {}/{}B{})",
            self.name(),
            st.device_batches,
            st.device_bytes,
            st.host_batches,
            st.host_bytes,
            st.disk_batches,
            st.disk_bytes,
            if self.is_finished() { ", finished" } else { "" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Column;

    fn batch(rows: usize) -> RecordBatch {
        RecordBatch::new(vec![
            Column::i64("k", (0..rows as i64).collect()),
            Column::f32("v", (0..rows).map(|i| i as f32).collect()),
        ])
        .unwrap()
    }

    #[test]
    fn push_pop_device_fifo() {
        let h = BatchHolder::new("t", MemEnv::test(1 << 20));
        h.push_batch(batch(10)).unwrap();
        h.push_batch(batch(20)).unwrap();
        let a = h.pop_device().unwrap().unwrap();
        assert_eq!(a.rows(), 10);
        let b = h.pop_device().unwrap().unwrap();
        assert_eq!(b.rows(), 20);
        assert!(h.pop_device().unwrap().is_none());
    }

    #[test]
    fn arena_accounting_tracks_pops() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("t", env.clone());
        h.push_batch(batch(100)).unwrap();
        let before = env.arena.in_use();
        assert!(before > 0);
        let db = h.pop_device().unwrap().unwrap();
        assert_eq!(env.arena.in_use(), before);
        drop(db);
        assert_eq!(env.arena.in_use(), 0);
    }

    #[test]
    fn spill_frees_device_and_roundtrips() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("t", env.clone());
        h.push_batch(batch(50)).unwrap();
        h.push_batch(batch(60)).unwrap();
        let freed = h.spill_one().unwrap();
        assert!(freed > 0);
        assert_eq!(h.stats().device_batches, 1);
        assert_eq!(h.stats().host_batches, 1);
        // order preserved: pop gives 50-row batch first
        assert_eq!(h.pop_device().unwrap().unwrap().rows(), 50);
        assert_eq!(h.pop_device().unwrap().unwrap().rows(), 60);
    }

    #[test]
    fn spill_prefers_newest_device_batch() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("t", env.clone());
        h.push_batch(batch(10)).unwrap();
        h.push_batch(batch(20)).unwrap();
        h.spill_one().unwrap();
        let st = h.stats();
        // the 20-row (newer) batch went to host
        assert_eq!(st.device_bytes, batch(10).byte_size());
    }

    #[test]
    fn full_demotion_chain_to_disk_and_back() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("t", env.clone());
        h.push_batch(batch(40)).unwrap();
        h.spill_one().unwrap();
        assert_eq!(h.stats().host_batches, 1);
        h.spill_host_one().unwrap();
        assert_eq!(h.stats().disk_batches, 1);
        assert!(env.spill.live_bytes() > 0);
        // promote disk -> host, then pop to device
        assert!(h.promote_one_to_host().unwrap());
        assert_eq!(h.stats().host_batches, 1);
        let db = h.pop_device().unwrap().unwrap();
        assert_eq!(db.batch, batch(40));
    }

    #[test]
    fn pop_from_disk_directly_works() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("t", env.clone());
        h.push_batch(batch(7)).unwrap();
        h.spill_one().unwrap();
        h.spill_host_one().unwrap();
        let db = h.pop_device().unwrap().unwrap();
        assert_eq!(db.batch, batch(7));
        assert_eq!(env.spill.live_bytes(), 0, "slot freed after reload");
    }

    #[test]
    fn oom_pop_preserves_batch_and_is_retryable() {
        // Arena too small to materialize the host-tier batch.
        let env = MemEnv::test(64);
        let h = BatchHolder::new("t", env.clone());
        h.push_batch_host(batch(100)).unwrap();
        let e = h.pop_device().unwrap_err();
        assert!(e.is_retryable());
        assert_eq!(h.len(), 1, "slot restored after failed pop");
        // encoded pop still drains it without device memory
        let bytes = h.pop_encoded().unwrap().unwrap();
        assert_eq!(RecordBatch::decode(&bytes.contiguous()).unwrap(), batch(100));
    }

    #[test]
    fn pop_encoded_hands_over_the_slab() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("t", env.clone());
        h.push_batch_host(batch(64)).unwrap();
        let pool = env.pinned.as_ref().unwrap();
        let held = pool.total_buffers() - pool.free_buffers();
        assert!(held > 0, "host push staged into the pool");
        let enc = h.pop_encoded().unwrap().unwrap();
        assert!(enc.is_pinned(), "host-pinned slot pops as a slab view");
        // the pop did not copy: the same buffers moved owner
        assert_eq!(pool.total_buffers() - pool.free_buffers(), held);
        assert_eq!(RecordBatch::decode(&enc.contiguous()).unwrap(), batch(64));
        drop(enc);
        assert_eq!(pool.free_buffers(), pool.total_buffers());
    }

    #[test]
    fn spill_and_promote_stay_pinned_without_codec() {
        // None-codec demotion writes the slab per-chunk; promotion
        // reads straight back into a slab. bounce_bytes counts exactly
        // one staging copy per direction, none in between.
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("t", env.clone());
        let pool = env.pinned.clone().unwrap();
        h.push_batch_host(batch(200)).unwrap();
        let after_push = pool.bounce_bytes();
        assert!(after_push > 0);
        h.spill_host_one().unwrap();
        assert_eq!(pool.bounce_bytes(), after_push, "demotion must not re-copy");
        assert_eq!(h.stats().disk_batches, 1);
        assert!(h.promote_one_to_host().unwrap());
        assert!(pool.bounce_bytes() > after_push, "reload lands in the pool");
        assert_eq!(h.stats().host_batches, 1);
        let db = h.pop_device().unwrap().unwrap();
        assert_eq!(db.batch, batch(200));
    }

    #[test]
    fn slab_backed_push_takes_no_extra_copy() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("rx", env.clone());
        let pool = env.pinned.clone().unwrap();
        let encoded = batch(40).encode();
        let slab = PinnedSlab::write(&pool, &encoded).unwrap();
        let staged = pool.bounce_bytes();
        h.push_host_bytes(StagedBytes::Pinned(SlabSlice::whole(slab))).unwrap();
        assert_eq!(pool.bounce_bytes(), staged, "push adopted the slab");
        assert_eq!(h.stats().host_batches, 1);
        assert_eq!(h.pop_device().unwrap().unwrap().batch, batch(40));
    }

    #[test]
    fn shared_slab_push_copies_for_correct_accounting() {
        // Two holders receiving the same Arc-shared slab (in-proc
        // broadcast) must not both adopt it: accounting would exceed
        // the pool's physical usage. The first push re-stages; once the
        // view is exclusive again, the second adopts.
        let env = MemEnv::test(1 << 20);
        let h1 = BatchHolder::new("a", env.clone());
        let h2 = BatchHolder::new("b", env.clone());
        let pool = env.pinned.clone().unwrap();
        let encoded = batch(50).encode();
        let slab = PinnedSlab::write(&pool, &encoded).unwrap();
        let view = SlabSlice::whole(slab);
        let sibling = view.clone(); // the broadcast's second frame
        assert!(!view.is_exclusive());
        h1.push_host_bytes(StagedBytes::Pinned(view)).unwrap();
        assert!(sibling.is_exclusive(), "first push released its ref");
        h2.push_host_bytes(StagedBytes::Pinned(sibling)).unwrap();
        // both holders own real, independent bytes
        assert_eq!(h1.pop_device().unwrap().unwrap().batch, batch(50));
        assert_eq!(h2.pop_device().unwrap().unwrap().batch, batch(50));
    }

    #[test]
    fn push_encoded_receives_network_frames() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("rx", env);
        let tier = h.push_encoded(batch(30).encode()).unwrap();
        assert_eq!(tier, Tier::Host);
        assert_eq!(h.pop_device().unwrap().unwrap().rows(), 30);
    }

    #[test]
    fn finish_semantics() {
        let h = BatchHolder::new("t", MemEnv::test(1 << 20));
        h.push_batch(batch(5)).unwrap();
        assert!(!h.is_exhausted());
        h.finish();
        assert!(h.is_finished());
        assert!(!h.is_exhausted());
        h.pop_device().unwrap();
        assert!(h.is_exhausted());
    }

    #[test]
    fn pushed_bytes_accumulate_for_estimation() {
        let h = BatchHolder::new("t", MemEnv::test(1 << 20));
        let b = batch(10);
        let sz = b.byte_size() as u64;
        h.push_batch(b).unwrap();
        h.push_batch(batch(10)).unwrap();
        assert_eq!(h.bytes_pushed(), 2 * sz);
        assert_eq!(h.batches_pushed(), 2);
    }

    #[test]
    fn spill_codec_compresses_on_disk() {
        let mut env = MemEnv::test(1 << 20);
        env.spill_codec = Codec::Zstd { level: 1 };
        let h = BatchHolder::new("t", env.clone());
        // highly compressible batch
        let b = RecordBatch::new(vec![Column::i64("k", vec![7; 4096])]).unwrap();
        let raw = b.byte_size() as u64;
        h.push_batch_host(b.clone()).unwrap();
        h.spill_host_one().unwrap();
        assert!(env.spill.live_bytes() < raw / 4, "{}", env.spill.live_bytes());
        assert_eq!(h.pop_device().unwrap().unwrap().batch, b);
    }

    #[test]
    fn stats_snapshot_consistent() {
        let h = BatchHolder::new("t", MemEnv::test(1 << 20));
        for _ in 0..3 {
            h.push_batch(batch(10)).unwrap();
        }
        h.spill_one().unwrap();
        let st = h.stats();
        assert_eq!(st.total_batches(), 3);
        assert_eq!(st.device_batches, 2);
        assert_eq!(st.host_batches, 1);
        assert!(st.total_bytes() > 0);
    }

    #[test]
    fn residency_tracks_the_demotion_chain() {
        let env = MemEnv::test(1 << 20);
        let h = BatchHolder::new("t", env.clone());
        h.push_batch(batch(50)).unwrap();
        let r = h.residency();
        assert!(r.device_bytes > 0 && r.total_bytes() == r.device_bytes);
        assert_eq!(r.device_frac(), 1.0);
        assert_eq!(r.spilled_frac(), 0.0);

        h.spill_one().unwrap();
        let r = h.residency();
        assert_eq!(r.device_bytes, 0);
        assert!(r.host_pinned_bytes > 0, "test env has a pool: host slot is pinned");
        assert_eq!(r.host_heap_bytes, 0);

        h.spill_host_one().unwrap();
        let r = h.residency();
        assert!(r.spilled_bytes > 0);
        assert_eq!(r.spilled_frac(), 1.0);
        assert_eq!(r.device_frac(), 0.0);

        h.promote_one_to_host().unwrap();
        let r = h.residency();
        assert_eq!(r.spilled_bytes, 0);
        assert!(r.host_pinned_bytes > 0);

        // stats() tier view stays consistent with the class view
        let st = h.stats();
        assert_eq!(st.host_bytes, r.host_pinned_bytes + r.host_heap_bytes);
        assert_eq!(st.total_bytes(), r.total_bytes());
    }

    #[test]
    fn residency_splits_pinned_from_heap_host_bytes() {
        // No pool: host pushes land in pageable memory -> HostHeap.
        let mut env = MemEnv::test(1 << 20);
        env.pinned = None;
        let h = BatchHolder::new("t", env);
        h.push_batch_host(batch(30)).unwrap();
        let r = h.residency();
        assert_eq!(r.host_pinned_bytes, 0);
        assert!(r.host_heap_bytes > 0);
        assert_eq!(h.stats().host_bytes, r.host_heap_bytes);
    }

    #[test]
    fn residency_merge_weighs_by_bytes() {
        let mut a = ResidencySnapshot { device_bytes: 100, ..Default::default() };
        let b = ResidencySnapshot { spilled_bytes: 300, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.total_bytes(), 400);
        assert_eq!(a.device_frac(), 0.25);
        assert_eq!(a.spilled_frac(), 0.75);
        // empty snapshot: nothing to move, counts as fully resident
        let e = ResidencySnapshot::default();
        assert_eq!(e.device_frac(), 1.0);
        assert_eq!(e.spilled_frac(), 0.0);
    }

    #[test]
    fn clones_share_identity() {
        let h = BatchHolder::new("t", MemEnv::test(1 << 20));
        let h2 = h.clone();
        let other = BatchHolder::new("t", MemEnv::test(1 << 20));
        assert_eq!(h.id(), h2.id());
        assert_ne!(h.id(), other.id());
    }

    #[test]
    fn concurrent_demote_promote_loses_nothing() {
        // The movement plane may demote and promote the same holder
        // from different threads. No batch may be lost, the run must
        // not deadlock, and every row must still pop out.
        let env = MemEnv::test(1 << 22);
        let h = BatchHolder::new("contended", env.clone());
        const BATCHES: usize = 24;
        for _ in 0..BATCHES {
            h.push_batch(batch(100)).unwrap();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mk = |f: fn(&BatchHolder)| {
            let h = h.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    f(&h);
                }
            })
        };
        let threads = vec![
            mk(|h| {
                let _ = h.demote_one(Tier::Device);
            }),
            mk(|h| {
                let _ = h.demote_one(Tier::Host);
            }),
            mk(|h| {
                let _ = h.promote_one();
            }),
            mk(|h| {
                let _ = h.promote_one();
            }),
        ];
        std::thread::sleep(std::time::Duration::from_millis(150));
        stop.store(true, Ordering::Relaxed);
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.stats().total_batches(), BATCHES, "{:?}", h.stats());
        let mut rows = 0;
        while let Some(db) = h.pop_device().unwrap() {
            rows += db.rows();
        }
        assert_eq!(rows, BATCHES * 100, "rows lost under contention");
    }
}
