//! Memory reservations + per-operator consumption history (§3.3.2).
//!
//! "Before they execute, Compute Executor tasks are required to reserve
//! (not allocate) memory with the Memory Executor. ... These memory
//! reservations help prevent out-of-memory errors while compute tasks
//! perform allocations during execution. Each Operator keeps track of
//! actual memory consumption of previously executed compute tasks,
//! which feed into a heuristic that determines how much memory to
//! reserve ... Compute tasks that run out of memory can be retried,
//! improve their estimations on subsequent runs, and be divided up."
//!
//! A [`Reservation`] is accounting-only: it carves headroom out of the
//! device arena's *reservable* budget without touching the arena's
//! in-use counter; task allocations then draw real arena bytes inside
//! that headroom. When a reservation cannot be granted, the governor
//! raises device pressure on the shared [`PressureEvent`]; the
//! Data-Movement executor spills and calls
//! [`MemoryGovernor::notify_freed`], waking the blocked reservation in
//! microseconds rather than on a polling tick.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::memory::pressure::PressureEvent;
use crate::memory::DeviceArena;
use crate::sync::{ranks, OrderedCondvar, OrderedMutex};
use crate::{Error, Result};

/// Grants and tracks reservations against one device arena.
#[derive(Clone)]
pub struct MemoryGovernor {
    inner: Arc<Inner>,
}

struct Inner {
    arena: DeviceArena,
    reserved: OrderedMutex<usize>,
    freed: OrderedCondvar,
    /// Raised when a reservation can't be granted; the Data-Movement
    /// executor answers by spilling, then calls `notify_freed`.
    pressure: OnceLock<Arc<PressureEvent>>,
    grants: AtomicU64,
    waits: AtomicU64,
    timeouts: AtomicU64,
}

impl MemoryGovernor {
    pub fn new(arena: DeviceArena) -> Self {
        MemoryGovernor {
            inner: Arc::new(Inner {
                arena,
                reserved: OrderedMutex::new(
                    ranks::GOVERNOR_RESERVED,
                    "governor.reserved",
                    0,
                ),
                freed: OrderedCondvar::new(),
                pressure: OnceLock::new(),
                grants: AtomicU64::new(0),
                waits: AtomicU64::new(0),
                timeouts: AtomicU64::new(0),
            }),
        }
    }

    /// Install the shared pressure event (the Data-Movement executor
    /// wires itself here — Insight B: reservations ask spilling for
    /// help rather than competing with it). One-shot.
    pub fn install_pressure(&self, event: Arc<PressureEvent>) {
        let _ = self.inner.pressure.set(event);
    }

    /// Raise device pressure for `bytes` without blocking — used by
    /// holders of accounting that can shed load themselves (the exchange
    /// coalescer flushes buffered builders on the next pressure epoch)
    /// when a `grow` is refused but parking is not an option.
    pub fn raise_pressure(&self, bytes: usize) {
        if let Some(ev) = self.inner.pressure.get() {
            ev.raise_device(bytes);
        }
    }

    /// Wake reservations blocked in [`MemoryGovernor::reserve`]. Called
    /// by the Data-Movement executor after demotions free arena bytes
    /// (arena frees don't pass through the governor's own lock, so the
    /// spiller delivers the wakeup).
    ///
    /// The notify happens *while holding* the ledger lock: a waiter
    /// re-checks its headroom predicate under that same lock, so a
    /// wakeup delivered without it could land between the waiter's
    /// check and its park and be lost (the reserve would then stall a
    /// full 20 ms re-raise chunk — the `Outbox::grant_credits` bug
    /// class, previously latent here).
    pub fn notify_freed(&self) {
        let reserved = self.inner.reserved.lock();
        self.inner.freed.notify_all(&reserved);
    }

    pub fn arena(&self) -> &DeviceArena {
        &self.inner.arena
    }

    /// Bytes currently promised to tasks.
    pub fn reserved(&self) -> usize {
        *self.inner.reserved.lock()
    }

    /// Headroom available for new reservations: capacity minus the
    /// larger of (actual in-use, promised) — conservative on both sides.
    pub fn available(&self) -> usize {
        let cap = self.inner.arena.capacity();
        let used = self.inner.arena.in_use().max(self.reserved());
        cap.saturating_sub(used)
    }

    pub fn grant_count(&self) -> u64 {
        self.inner.grants.load(Ordering::Relaxed)
    }

    pub fn wait_count(&self) -> u64 {
        self.inner.waits.load(Ordering::Relaxed)
    }

    pub fn timeout_count(&self) -> u64 {
        self.inner.timeouts.load(Ordering::Relaxed)
    }

    /// Try to reserve immediately.
    pub fn try_reserve(&self, bytes: usize) -> Option<Reservation> {
        let mut reserved = self.inner.reserved.lock();
        let used = self.inner.arena.in_use().max(*reserved);
        if used + bytes <= self.inner.arena.capacity() {
            *reserved += bytes;
            self.inner.grants.fetch_add(1, Ordering::Relaxed);
            Some(Reservation { gov: self.clone(), bytes })
        } else {
            None
        }
    }

    /// Reserve, raising device pressure and waiting (event-driven, via
    /// [`MemoryGovernor::notify_freed`]) up to `timeout` if memory is
    /// scarce.
    pub fn reserve(&self, bytes: usize, timeout: Duration) -> Result<Reservation> {
        if let Some(r) = self.try_reserve(bytes) {
            return Ok(r);
        }
        self.inner.waits.fetch_add(1, Ordering::Relaxed);
        // Ask the movement plane for help, then park on the condvar.
        self.raise_pressure(bytes);
        let deadline = Instant::now() + timeout;
        let mut reserved = self.inner.reserved.lock();
        loop {
            let used = self.inner.arena.in_use().max(*reserved);
            if used + bytes <= self.inner.arena.capacity() {
                *reserved += bytes;
                self.inner.grants.fetch_add(1, Ordering::Relaxed);
                return Ok(Reservation { gov: self.clone(), bytes });
            }
            let now = Instant::now();
            if now >= deadline {
                self.inner.timeouts.fetch_add(1, Ordering::Relaxed);
                return Err(Error::ReservationTimeout {
                    requested: bytes,
                    tier: "device",
                    waited_ms: timeout.as_millis() as u64,
                });
            }
            // The wakeup path is notify_freed/release; the timeout
            // chunk only bounds staleness for arena frees that bypass
            // the movement plane (a compute task dropping its device
            // batches), re-raising in case the first spill round fell
            // short.
            let (guard, timed_out) = self
                .inner
                .freed
                .wait_timeout(reserved, (deadline - now).min(Duration::from_millis(20)));
            reserved = guard;
            if timed_out {
                drop(reserved);
                self.raise_pressure(bytes);
                reserved = self.inner.reserved.lock();
            }
        }
    }

    fn release(&self, bytes: usize) {
        let mut reserved = self.inner.reserved.lock();
        *reserved -= bytes.min(*reserved);
        self.inner.freed.notify_all(&reserved);
    }
}

/// RAII reservation guard.
pub struct Reservation {
    gov: MemoryGovernor,
    bytes: usize,
}

impl Reservation {
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Grow an under-estimated reservation mid-task (non-blocking; the
    /// caller treats failure as retryable OOM and splits the task).
    pub fn grow(&mut self, extra: usize) -> Result<()> {
        match self.gov.try_reserve(extra) {
            Some(r) => {
                std::mem::forget(r); // fold into self
                self.bytes += extra;
                Ok(())
            }
            None => Err(Error::DeviceOom {
                requested: extra,
                capacity: self.gov.inner.arena.capacity(),
                in_use: self.gov.inner.arena.in_use(),
            }),
        }
    }

    /// Hand back part of the reservation (clamped to what is held),
    /// waking anyone parked in [`MemoryGovernor::reserve`]. The inverse
    /// of [`Reservation::grow`] — accounting that tracks a fluctuating
    /// buffer (the exchange coalescer's builder bytes) grows on append
    /// and shrinks on flush instead of re-reserving from scratch.
    pub fn shrink(&mut self, by: usize) {
        let by = by.min(self.bytes);
        if by > 0 {
            self.bytes -= by;
            self.gov.release(by);
        }
    }
}

impl Drop for Reservation {
    fn drop(&mut self) {
        self.gov.release(self.bytes);
    }
}

impl std::fmt::Debug for Reservation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Reservation({} bytes)", self.bytes)
    }
}

/// Per-operator memory consumption history (§3.3.2): an EWMA of actual
/// usage with a safety factor, refined after every task and inflated
/// after every OOM retry.
pub struct OpMemoryHistory {
    /// EWMA of observed peak bytes per task.
    ewma: Mutex<f64>,
    /// Multiplier applied to the estimate (grows on OOM, decays on
    /// success down to `BASE_SAFETY`).
    safety: Mutex<f64>,
    samples: AtomicU64,
    ooms: AtomicU64,
}

const BASE_SAFETY: f64 = 1.25;
const OOM_BACKOFF: f64 = 1.6;
const EWMA_ALPHA: f64 = 0.3;

impl Default for OpMemoryHistory {
    fn default() -> Self {
        OpMemoryHistory {
            ewma: Mutex::new(0.0),
            safety: Mutex::new(BASE_SAFETY),
            samples: AtomicU64::new(0),
            ooms: AtomicU64::new(0),
        }
    }
}

impl OpMemoryHistory {
    pub fn new() -> Self {
        Self::default()
    }

    /// Estimate the reservation for a task whose input payload is
    /// `input_bytes`. With no history, assume output ≈ input with the
    /// safety factor; with history, scale the EWMA.
    pub fn estimate(&self, input_bytes: usize) -> usize {
        let ewma = *self.ewma.lock().unwrap();
        let safety = *self.safety.lock().unwrap();
        let base = if self.samples.load(Ordering::Relaxed) == 0 {
            // no history: input + same-size output
            (input_bytes * 2) as f64
        } else {
            ewma
        };
        (base * safety) as usize
    }

    /// Record the actual peak consumption of a completed task.
    pub fn record_success(&self, actual_bytes: usize) {
        let mut ewma = self.ewma.lock().unwrap();
        let n = self.samples.fetch_add(1, Ordering::Relaxed);
        *ewma = if n == 0 {
            actual_bytes as f64
        } else {
            *ewma * (1.0 - EWMA_ALPHA) + actual_bytes as f64 * EWMA_ALPHA
        };
        // decay safety back toward base after successes
        let mut s = self.safety.lock().unwrap();
        *s = (*s * 0.9).max(BASE_SAFETY);
    }

    /// Record an OOM: future estimates grow (§3.3.2 "improve their
    /// estimations on subsequent runs").
    pub fn record_oom(&self) {
        self.ooms.fetch_add(1, Ordering::Relaxed);
        let mut s = self.safety.lock().unwrap();
        *s = (*s * OOM_BACKOFF).min(8.0);
    }

    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    pub fn ooms(&self) -> u64 {
        self.ooms.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gov(cap: usize) -> MemoryGovernor {
        MemoryGovernor::new(DeviceArena::new(cap))
    }

    #[test]
    fn reserve_and_release() {
        let g = gov(1000);
        let r1 = g.try_reserve(600).unwrap();
        assert_eq!(g.reserved(), 600);
        assert!(g.try_reserve(500).is_none());
        drop(r1);
        assert_eq!(g.reserved(), 0);
        assert!(g.try_reserve(500).is_some());
    }

    #[test]
    fn reservations_respect_actual_arena_usage() {
        let g = gov(1000);
        let _real = g.arena().alloc(700).unwrap();
        // only 300 reservable even though nothing is "reserved"
        assert!(g.try_reserve(400).is_none());
        assert!(g.try_reserve(300).is_some());
    }

    #[test]
    fn pressure_event_raised_and_wait_woken_by_notify() {
        let g = gov(1000);
        let hold = Arc::new(Mutex::new(Some(g.arena().alloc(900).unwrap())));
        let ev = PressureEvent::new();
        g.install_pressure(ev.clone());
        // A stand-in movement plane: park on the event, "spill" (drop
        // the big allocation), then deliver the wakeup.
        let h2 = hold.clone();
        let g2 = g.clone();
        let ev2 = ev.clone();
        let mover = std::thread::spawn(move || {
            let snap = ev2.wait(Duration::from_secs(2));
            assert!(snap.device_need >= 500, "reserve must raise its need");
            h2.lock().unwrap().take();
            g2.notify_freed();
        });
        let r = g.reserve(500, Duration::from_secs(2)).unwrap();
        assert_eq!(r.bytes(), 500);
        assert!(ev.raise_count() >= 1);
        assert_eq!(g.wait_count(), 1);
        mover.join().unwrap();
    }

    #[test]
    fn reservation_times_out_with_typed_error() {
        let g = gov(100);
        let _r = g.try_reserve(100).unwrap();
        let e = g.reserve(50, Duration::from_millis(40)).unwrap_err();
        assert!(matches!(e, Error::ReservationTimeout { .. }));
        assert!(e.is_retryable());
        assert_eq!(g.timeout_count(), 1);
    }

    #[test]
    fn grow_succeeds_within_headroom() {
        let g = gov(1000);
        let mut r = g.try_reserve(400).unwrap();
        r.grow(300).unwrap();
        assert_eq!(r.bytes(), 700);
        assert_eq!(g.reserved(), 700);
        assert!(r.grow(400).is_err());
        drop(r);
        assert_eq!(g.reserved(), 0);
    }

    #[test]
    fn shrink_returns_headroom_and_clamps() {
        let g = gov(1000);
        let mut r = g.try_reserve(600).unwrap();
        r.shrink(200);
        assert_eq!(r.bytes(), 400);
        assert_eq!(g.reserved(), 400);
        // freed headroom is immediately reservable again
        let other = g.try_reserve(600).unwrap();
        drop(other);
        // shrink past the held amount clamps to zero, never underflows
        r.shrink(10_000);
        assert_eq!(r.bytes(), 0);
        assert_eq!(g.reserved(), 0);
        drop(r);
        assert_eq!(g.reserved(), 0);
    }

    #[test]
    fn history_starts_conservative_then_tracks() {
        let h = OpMemoryHistory::new();
        // no history: 2x input * 1.25 safety
        assert_eq!(h.estimate(1000), 2500);
        h.record_success(1500);
        let e = h.estimate(1000);
        assert!(e >= 1500 && e < 2500, "{e}");
        // converges toward actuals
        for _ in 0..20 {
            h.record_success(1500);
        }
        let e = h.estimate(123);
        assert!((1800..2000).contains(&e), "{e}"); // 1500 * 1.25
    }

    #[test]
    fn oom_inflates_estimates() {
        let h = OpMemoryHistory::new();
        h.record_success(1000);
        let before = h.estimate(0);
        h.record_oom();
        let after = h.estimate(0);
        assert!(after as f64 >= before as f64 * 1.5, "{before} -> {after}");
        assert_eq!(h.ooms(), 1);
        // success decays it back down eventually
        for _ in 0..30 {
            h.record_success(1000);
        }
        let recovered = h.estimate(0);
        assert!(recovered <= before, "{recovered} vs {before}");
    }

    #[test]
    fn concurrent_reserves_never_exceed_capacity() {
        let g = gov(10_000);
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let g = g.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if let Some(r) = g.try_reserve(1_000) {
                            assert!(g.reserved() <= 10_000);
                            drop(r);
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(g.reserved(), 0);
    }
}
