//! Memory subsystem: the three tiers the paper moves data across
//! (§3.1, §3.4) and the machinery for doing so safely.
//!
//! * [`device::DeviceArena`] — capacity-tracked "GPU" memory (DESIGN.md
//!   §Hardware-Adaptation: real allocations accounted against a
//!   configurable capacity, standing in for the 80 GiB of an A100).
//! * [`pinned::PinnedPool`] — the paper's fixed-size page-locked host
//!   buffer pool (§3.4, Figure 3B): pre-allocated at engine init,
//!   `mlock(2)`-backed when permitted, also used as network bounce
//!   buffers and pre-load staging.
//! * [`spill::SpillStore`] — storage tier: segmented spill files on
//!   local disk with lock-free positional I/O.
//! * [`batch_holder::BatchHolder`] — the paper's Batch Holder: "a data
//!   container that guarantees that inputs can always be stored
//!   somewhere in the system, even when the intended target memory is
//!   full" (§3.1).
//! * [`reservation::MemoryGovernor`] — reservations + per-operator
//!   consumption history (§3.3.2).
//! * [`pressure::PressureEvent`] — the condvar-backed event the tiers
//!   raise on threshold crossings and failed reservations; the
//!   Data-Movement executor ([`crate::executors::movement`]) parks on
//!   it instead of polling utilization.

pub mod batch_holder;
pub mod device;
pub mod pinned;
pub mod pressure;
pub mod reservation;
pub mod spill;

pub use batch_holder::{BatchHolder, HolderStats, ResidencyClass, ResidencySnapshot};
pub use device::{DeviceAlloc, DeviceArena};
pub use pinned::{PinnedBuf, PinnedPool, PinnedSlab, SlabSlice, SlabWriter, StagedBytes};
pub use pressure::{PressureEvent, PressureSnapshot};
pub use reservation::{MemoryGovernor, OpMemoryHistory, Reservation};
pub use spill::SpillStore;

/// Where a piece of data currently lives. Ordered by "distance" from the
/// device: spilling demotes rightward, pre-loading promotes leftward.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    Device,
    Host,
    Disk,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Device => "device",
            Tier::Host => "host",
            Tier::Disk => "disk",
        }
    }

    /// The tier data is demoted to when this one is under pressure.
    pub fn spill_target(self) -> Option<Tier> {
        match self {
            Tier::Device => Some(Tier::Host),
            Tier::Host => Some(Tier::Disk),
            Tier::Disk => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_ordering_matches_distance() {
        assert!(Tier::Device < Tier::Host);
        assert!(Tier::Host < Tier::Disk);
    }

    #[test]
    fn spill_chain_terminates() {
        assert_eq!(Tier::Device.spill_target(), Some(Tier::Host));
        assert_eq!(Tier::Host.spill_target(), Some(Tier::Disk));
        assert_eq!(Tier::Disk.spill_target(), None);
    }
}
