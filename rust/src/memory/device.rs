//! Capacity-tracked device ("GPU") memory arena.
//!
//! The simulated A100/L4: allocations are real host memory, but every
//! byte is accounted against a configurable capacity so that the memory
//! executor, reservations, and spilling face the same pressure the paper
//! engineers for. Transfers into/out of the arena are paced by the PCIe
//! [`crate::sim::Throttle`] at the call sites (batch holder / runtime).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::memory::pressure::PressureEvent;
use crate::{Error, Result};

/// Shared accounting state of one device's memory.
#[derive(Clone)]
pub struct DeviceArena {
    inner: Arc<Inner>,
}

/// Event-driven spill trigger: installed once at worker startup by the
/// Data-Movement executor (§3.3.2 — pressure is signalled, not polled).
struct PressureHook {
    event: Arc<PressureEvent>,
    /// Bytes of in-use at which a crossing raises device pressure.
    threshold: usize,
}

struct Inner {
    capacity: usize,
    in_use: AtomicU64,
    /// High-water mark, for reports.
    peak: AtomicU64,
    /// Lifetime totals.
    allocs: AtomicU64,
    failures: AtomicU64,
    pressure: OnceLock<PressureHook>,
}

impl DeviceArena {
    pub fn new(capacity: usize) -> Self {
        DeviceArena {
            inner: Arc::new(Inner {
                capacity,
                in_use: AtomicU64::new(0),
                peak: AtomicU64::new(0),
                allocs: AtomicU64::new(0),
                failures: AtomicU64::new(0),
                pressure: OnceLock::new(),
            }),
        }
    }

    /// Install the shared pressure event. A successful allocation that
    /// crosses `watermark * capacity` raises device pressure by the
    /// overage; a failed allocation raises it by the requested size.
    /// One-shot: later installs are ignored (one movement plane per
    /// arena).
    pub fn install_pressure(&self, event: Arc<PressureEvent>, watermark: f64) {
        let threshold = (self.capacity() as f64 * watermark) as usize;
        let _ = self.inner.pressure.set(PressureHook { event, threshold });
    }

    /// The installed pressure event, if the movement plane attached one.
    /// The arena is on every `MemEnv`, so this is where other buffering
    /// subsystems (the coalescing exchange) find the worker's shared
    /// event to watch its memory-pressure epoch. `None` before the
    /// Data-Movement executor starts (unit tests): pressure-aware
    /// behavior simply stays off.
    pub fn pressure_event(&self) -> Option<Arc<PressureEvent>> {
        self.inner.pressure.get().map(|h| h.event.clone())
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    pub fn in_use(&self) -> usize {
        self.inner.in_use.load(Ordering::Relaxed) as usize
    }

    pub fn free(&self) -> usize {
        self.capacity().saturating_sub(self.in_use())
    }

    pub fn peak(&self) -> usize {
        self.inner.peak.load(Ordering::Relaxed) as usize
    }

    pub fn alloc_count(&self) -> u64 {
        self.inner.allocs.load(Ordering::Relaxed)
    }

    pub fn failure_count(&self) -> u64 {
        self.inner.failures.load(Ordering::Relaxed)
    }

    /// Fraction of capacity in use (memory-executor watermark input).
    pub fn utilization(&self) -> f64 {
        if self.inner.capacity == 0 {
            return 1.0;
        }
        self.in_use() as f64 / self.inner.capacity as f64
    }

    /// Account an `n`-byte device allocation. Returns a guard that
    /// releases the bytes on drop, or [`Error::DeviceOom`] (retryable —
    /// the compute executor will spill/split/retry, §3.3.2).
    pub fn alloc(&self, n: usize) -> Result<DeviceAlloc> {
        let inner = &self.inner;
        // CAS loop: in_use + n must not exceed capacity.
        let mut cur = inner.in_use.load(Ordering::Relaxed);
        loop {
            let next = cur as usize + n;
            if next > inner.capacity {
                inner.failures.fetch_add(1, Ordering::Relaxed);
                // A failed allocation is the sharpest pressure signal:
                // wake the movement plane immediately.
                if let Some(h) = inner.pressure.get() {
                    h.event.raise_device(n);
                }
                return Err(Error::DeviceOom {
                    requested: n,
                    capacity: inner.capacity,
                    in_use: cur as usize,
                });
            }
            match inner.in_use.compare_exchange_weak(
                cur,
                next as u64,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    // Watermark crossing (was below, now above): raise
                    // by the overage so spilling starts before OOM.
                    if let Some(h) = inner.pressure.get() {
                        if cur as usize <= h.threshold && next > h.threshold {
                            h.event.raise_device(next - h.threshold);
                        }
                    }
                    break;
                }
                Err(c) => cur = c,
            }
        }
        inner.allocs.fetch_add(1, Ordering::Relaxed);
        inner.peak.fetch_max(self.in_use() as u64, Ordering::Relaxed);
        Ok(DeviceAlloc { arena: self.clone(), bytes: n })
    }

    fn release(&self, n: usize) {
        self.inner.in_use.fetch_sub(n as u64, Ordering::AcqRel);
    }
}

/// RAII guard for accounted device bytes.
pub struct DeviceAlloc {
    arena: DeviceArena,
    bytes: usize,
}

impl DeviceAlloc {
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Shrink the accounted size (a compute task over-reserved; return
    /// the unneeded bytes early).
    pub fn shrink_to(&mut self, n: usize) {
        if n < self.bytes {
            self.arena.release(self.bytes - n);
            self.bytes = n;
        }
    }
}

impl Drop for DeviceAlloc {
    fn drop(&mut self) {
        self.arena.release(self.bytes);
    }
}

impl std::fmt::Debug for DeviceAlloc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DeviceAlloc({} bytes)", self.bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_release_accounting() {
        let a = DeviceArena::new(1000);
        let g1 = a.alloc(400).unwrap();
        let g2 = a.alloc(500).unwrap();
        assert_eq!(a.in_use(), 900);
        assert_eq!(a.free(), 100);
        drop(g1);
        assert_eq!(a.in_use(), 500);
        drop(g2);
        assert_eq!(a.in_use(), 0);
        assert_eq!(a.peak(), 900);
    }

    #[test]
    fn oom_is_reported_with_sizes() {
        let a = DeviceArena::new(100);
        let _g = a.alloc(80).unwrap();
        match a.alloc(30) {
            Err(Error::DeviceOom { requested, capacity, in_use }) => {
                assert_eq!((requested, capacity, in_use), (30, 100, 80));
            }
            other => panic!("expected OOM, got {other:?}"),
        }
        assert_eq!(a.failure_count(), 1);
    }

    #[test]
    fn shrink_returns_bytes() {
        let a = DeviceArena::new(100);
        let mut g = a.alloc(100).unwrap();
        assert!(a.alloc(1).is_err());
        g.shrink_to(40);
        assert_eq!(a.in_use(), 40);
        let _g2 = a.alloc(60).unwrap();
    }

    #[test]
    fn concurrent_alloc_never_oversubscribes() {
        let a = DeviceArena::new(10_000);
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let a = a.clone();
                std::thread::spawn(move || {
                    let mut held = Vec::new();
                    for _ in 0..100 {
                        if let Ok(g) = a.alloc(100) {
                            assert!(a.in_use() <= a.capacity());
                            held.push(g);
                            if held.len() > 5 {
                                held.clear();
                            }
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(a.in_use(), 0);
    }

    #[test]
    fn utilization_bounds() {
        let a = DeviceArena::new(100);
        assert_eq!(a.utilization(), 0.0);
        let _g = a.alloc(50).unwrap();
        assert!((a.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn pressure_raised_on_crossing_and_failure() {
        let a = DeviceArena::new(100);
        let ev = PressureEvent::new();
        a.install_pressure(ev.clone(), 0.5);
        let _g1 = a.alloc(40).unwrap();
        assert!(ev.take().is_empty(), "below watermark: no signal");
        let _g2 = a.alloc(30).unwrap(); // 70 > 50: crossing
        assert_eq!(ev.take().device_need, 20);
        let _g3 = a.alloc(20).unwrap(); // already above: no re-raise
        assert!(ev.take().is_empty());
        assert!(a.alloc(50).is_err()); // failure always raises
        assert_eq!(ev.take().device_need, 50);
    }
}
