//! Fixed-size page-locked host buffer pool (§3.4, Figure 3B).
//!
//! "Large amounts of page-locked memory are slow to allocate ... the
//! engine has a pool of pre-allocated fixed-size page-locked buffers
//! which is allocated during engine initialization. Data from all
//! columns is placed into these buffers, allowing a single column's
//! contents to overlap multiple buffers. This approach provides
//! resilience to memory fragmentation at the cost of a small unused
//! block of memory per batch."
//!
//! The buffers here are real: backed by one contiguous region allocated
//! once at pool construction and `mlock(2)`ed when the RLIMIT permits
//! (gracefully degrading to plain memory otherwise — the *layout*
//! discipline, which is what the paper's Figure 3B is about, is
//! identical either way). The same pool doubles as the network bounce
//! buffer and pre-load staging area, exactly as in §3.4.
//!
//! ## The writer API and the one-bounce discipline
//!
//! [`PinnedSlab`] is the *single byte-carrier* of the data plane: the
//! pre-loader's staging pages, the Batch Holder's host tier, the
//! network's payloads, and the spill path all hold the same slabs.
//! Three pieces make the hot paths single-copy:
//!
//! * [`SlabWriter`] — incremental fill: acquire-buffers-as-you-go (or
//!   reserve all up front with [`SlabWriter::with_capacity`], so a dry
//!   pool fails *before* a socket or file has been half-consumed),
//!   with an [`std::io::Write`] impl so object-store reads, codec
//!   decompressors, and socket receives land bytes in pinned memory
//!   directly. [`PinnedSlab::from_reader`] wraps the common
//!   read-exactly-N-bytes case (network receive path).
//! * [`SlabSlice`] — a cheap `Arc`-shared view into a slab, so the
//!   pre-loader can hand out per-column pages of one coalesced fetch
//!   and the receive path can strip a codec prelude without copying.
//! * Chunk iteration ([`PinnedSlab::chunk_slices`],
//!   [`SlabSlice::chunks`]) — the vectored-I/O side: the TCP back-end
//!   `write_vectored`s slab chunks after a 21-byte header-encode
//!   (`Frame::encode_header`), and the spill tier `write_all_at`s each
//!   chunk at its own offset, so neither path ever reassembles a slab
//!   into a heap `Vec` (`PinnedSlab::read` remains for device uploads
//!   and tests only).
//!
//! The pool keeps cumulative `bounce_bytes` (bytes staged into slabs
//! *from outside the pool* — heap buffers, sockets, disk reads) and
//! `waste_bytes` (Figure-3B unused tails) counters, published as
//! worker metrics by the Data-Movement executor. Pool-to-pool
//! transforms (compressing a holder's slab for the wire, decompressing
//! a received slab payload) write through a
//! [`SlabWriter::count_bounce`]`(false)` writer: the bytes were already
//! counted when they first entered the pool, so a codec-enabled send no
//! longer double-counts. `codec_heap_fallback_bytes` records payload
//! bytes a codec had to stage on the heap because the pool was dry —
//! the §3.4 degradation gauge.

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::memory::pressure::PressureEvent;
use crate::{Error, Result};

/// Shared pool of fixed-size buffers carved from one pinned region.
#[derive(Clone)]
pub struct PinnedPool {
    inner: Arc<Inner>,
}

struct Inner {
    buf_size: usize,
    /// Base of the contiguous region (never reallocated).
    region: Region,
    free: Mutex<Vec<u32>>,
    available: Condvar,
    total: usize,
    mlocked: bool,
    acquires: AtomicU64,
    exhaustions: AtomicU64,
    /// Cumulative bytes copied *into* slabs (the bounce copies this
    /// module exists to make cheap and count).
    bounce_bytes: AtomicU64,
    /// Cumulative unused tail bytes of finished slabs (Figure 3B's
    /// "small unused block of memory per batch", aggregated).
    waste_bytes: AtomicU64,
    /// Payload bytes a codec staged on the heap because the pool was
    /// dry (compress or decompress fallback) — pool-dry operation is
    /// legal but slow, and this gauge makes it visible.
    codec_fallback_bytes: AtomicU64,
    /// Raised with host-tier pressure whenever the pool runs dry, so
    /// the Data-Movement executor demotes host data to disk (§3.4: the
    /// pool doubles as bounce buffer and staging area — exhaustion here
    /// stalls network receives and pre-loads alike).
    pressure: OnceLock<Arc<PressureEvent>>,
}

/// One contiguous, optionally mlocked allocation.
struct Region {
    ptr: *mut u8,
    len: usize,
}

// The region is only accessed through disjoint per-buffer slices handed
// out under the free-list lock; the raw pointer itself is immutable.
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

impl Drop for Region {
    fn drop(&mut self) {
        unsafe {
            libc::munlock(self.ptr as *const libc::c_void, self.len);
            let layout = std::alloc::Layout::from_size_align(self.len, 4096).unwrap();
            std::alloc::dealloc(self.ptr, layout);
        }
    }
}

impl PinnedPool {
    /// Allocate `buffers` buffers of `buf_size` bytes each, up front.
    /// Attempts to `mlock` the region; falls back to unpinned memory if
    /// the rlimit forbids it (check [`PinnedPool::is_mlocked`]).
    pub fn new(buf_size: usize, buffers: usize) -> Result<Self> {
        assert!(buf_size > 0 && buffers > 0);
        let len = buf_size * buffers;
        let layout = std::alloc::Layout::from_size_align(len, 4096)
            .map_err(|e| Error::internal(format!("pinned layout: {e}")))?;
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) };
        if ptr.is_null() {
            return Err(Error::internal("pinned pool allocation failed"));
        }
        let mlocked =
            unsafe { libc::mlock(ptr as *const libc::c_void, len) == 0 };
        Ok(PinnedPool {
            inner: Arc::new(Inner {
                buf_size,
                region: Region { ptr, len },
                free: Mutex::new((0..buffers as u32).rev().collect()),
                available: Condvar::new(),
                total: buffers,
                mlocked,
                acquires: Default::default(),
                exhaustions: Default::default(),
                bounce_bytes: Default::default(),
                waste_bytes: Default::default(),
                codec_fallback_bytes: Default::default(),
                pressure: OnceLock::new(),
            }),
        })
    }

    /// Install the shared pressure event (one-shot; later installs are
    /// ignored).
    pub fn install_pressure(&self, event: Arc<PressureEvent>) {
        let _ = self.inner.pressure.set(event);
    }

    fn raise_pressure(&self, bytes: usize) {
        if let Some(ev) = self.inner.pressure.get() {
            ev.raise_host(bytes);
        }
    }

    pub fn buf_size(&self) -> usize {
        self.inner.buf_size
    }

    pub fn total_buffers(&self) -> usize {
        self.inner.total
    }

    pub fn free_buffers(&self) -> usize {
        self.inner.free.lock().unwrap().len()
    }

    pub fn is_mlocked(&self) -> bool {
        self.inner.mlocked
    }

    pub fn acquire_count(&self) -> u64 {
        self.inner.acquires.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn exhaustion_count(&self) -> u64 {
        self.inner.exhaustions.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Cumulative bytes staged into slabs (one bounce copy each).
    pub fn bounce_bytes(&self) -> u64 {
        self.inner.bounce_bytes.load(Ordering::Relaxed)
    }

    /// Cumulative unused tail bytes of finished slabs.
    pub fn waste_bytes(&self) -> u64 {
        self.inner.waste_bytes.load(Ordering::Relaxed)
    }

    /// Cumulative payload bytes a codec staged on the heap because the
    /// pool was dry (pool-dry operation indicator).
    pub fn codec_heap_fallback_bytes(&self) -> u64 {
        self.inner.codec_fallback_bytes.load(Ordering::Relaxed)
    }

    /// Record `n` payload bytes taking a codec's heap fallback.
    pub fn note_codec_fallback(&self, n: usize) {
        self.inner.codec_fallback_bytes.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn note_bounce(&self, n: usize) {
        self.inner.bounce_bytes.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn note_waste(&self, n: usize) {
        self.inner.waste_bytes.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Publish pool-level counters into a worker metrics registry
    /// (idempotent gauge sets; the Data-Movement executor calls this on
    /// every planning pass).
    pub fn publish_metrics(&self, m: &crate::metrics::Metrics) {
        m.gauge("pinned.free_buffers").set(self.free_buffers() as i64);
        m.gauge("pinned.acquires").set(self.acquire_count() as i64);
        m.gauge("pinned.exhaustions").set(self.exhaustion_count() as i64);
        m.gauge("pinned.bounce_bytes").set(self.bounce_bytes() as i64);
        m.gauge("pinned.waste_bytes").set(self.waste_bytes() as i64);
        m.gauge("codec.heap_fallback_bytes")
            .set(self.codec_heap_fallback_bytes() as i64);
    }

    /// Take one buffer, failing immediately if the pool is dry (the
    /// caller decides whether to spill or wait).
    pub fn try_acquire(&self) -> Result<PinnedBuf> {
        self.try_acquire_inner(true)
    }

    /// [`PinnedPool::try_acquire`] whose shortfall does **not** raise
    /// host pressure. For callers with a mandatory heap fallback that
    /// must stay pressure-neutral — the shuffle staging path flushes on
    /// the very pressure epoch a raise here would advance, so raising
    /// would re-arm its own flush trigger on every dry-pool send.
    pub fn try_acquire_quiet(&self) -> Result<PinnedBuf> {
        self.try_acquire_inner(false)
    }

    fn try_acquire_inner(&self, raise: bool) -> Result<PinnedBuf> {
        let mut free = self.inner.free.lock().unwrap();
        match free.pop() {
            Some(idx) => {
                self.inner
                    .acquires
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(PinnedBuf { pool: self.clone(), idx })
            }
            None => {
                self.inner
                    .exhaustions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if raise {
                    self.raise_pressure(self.inner.buf_size);
                }
                Err(Error::PinnedExhausted { requested: 1, available: 0 })
            }
        }
    }

    /// Take one buffer, blocking until one frees up or `timeout`. Dry
    /// pool raises host pressure before parking so the Data-Movement
    /// executor can demote host data and free buffers while we wait.
    pub fn acquire_timeout(&self, timeout: std::time::Duration) -> Result<PinnedBuf> {
        let deadline = std::time::Instant::now() + timeout;
        let mut free = self.inner.free.lock().unwrap();
        loop {
            if let Some(idx) = free.pop() {
                self.inner
                    .acquires
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Ok(PinnedBuf { pool: self.clone(), idx });
            }
            self.raise_pressure(self.inner.buf_size);
            let now = std::time::Instant::now();
            if now >= deadline {
                self.inner
                    .exhaustions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Err(Error::PinnedExhausted { requested: 1, available: 0 });
            }
            let (guard, _) = self
                .inner
                .available
                .wait_timeout(free, deadline - now)
                .unwrap();
            free = guard;
        }
    }

    fn release(&self, idx: u32) {
        let mut free = self.inner.free.lock().unwrap();
        debug_assert!(!free.contains(&idx), "double release of pinned buf {idx}");
        free.push(idx);
        // Notify while the lock is held: a waiter that has re-checked
        // the (empty) free list but not yet parked would miss a signal
        // sent after the guard drops (lost-wakeup defense — see
        // CONCURRENCY.md on wait/notify pairings).
        self.inner.available.notify_one();
    }

    fn slice_ptr(&self, idx: u32) -> *mut u8 {
        debug_assert!((idx as usize) < self.inner.total);
        unsafe { self.inner.region.ptr.add(idx as usize * self.inner.buf_size) }
    }
}

/// Exclusive handle to one fixed-size buffer; returns to the pool on
/// drop.
pub struct PinnedBuf {
    pool: PinnedPool,
    idx: u32,
}

impl PinnedBuf {
    pub fn len(&self) -> usize {
        self.pool.inner.buf_size
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.pool.slice_ptr(self.idx), self.len()) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        unsafe {
            std::slice::from_raw_parts_mut(self.pool.slice_ptr(self.idx), self.len())
        }
    }
}

impl Drop for PinnedBuf {
    fn drop(&mut self) {
        self.pool.release(self.idx);
    }
}

impl std::fmt::Debug for PinnedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PinnedBuf#{}({} bytes)", self.idx, self.len())
    }
}

/// A logical byte region spanning one or more pool buffers — the Figure
/// 3B layout, where "a single column's contents [can] overlap multiple
/// buffers" and the final buffer's tail is "a small unused block".
pub struct PinnedSlab {
    bufs: Vec<PinnedBuf>,
    len: usize,
}

impl PinnedSlab {
    /// Copy `data` into freshly acquired pool buffers (all-or-nothing:
    /// a pool without room for the whole payload fails up front and
    /// raises host pressure for the shortfall).
    pub fn write(pool: &PinnedPool, data: &[u8]) -> Result<PinnedSlab> {
        let mut w = SlabWriter::with_capacity(pool, data.len())?;
        w.write_bytes(data)?;
        Ok(w.finish())
    }

    /// Read exactly `len` bytes from `r` straight into pool buffers —
    /// the network receive path's bounce. Every buffer is acquired
    /// *before* the first read, so a dry pool fails cleanly without
    /// consuming anything from the reader (the caller falls back to a
    /// heap read); an I/O error mid-fill is fatal to the stream.
    pub fn from_reader(
        pool: &PinnedPool,
        r: &mut impl std::io::Read,
        len: usize,
    ) -> Result<PinnedSlab> {
        let mut w = SlabWriter::with_capacity(pool, len)?;
        w.fill_positional(len, |_, buf| r.read_exact(buf))?;
        Ok(w.finish())
    }

    /// Logical byte length (excludes the unused tail of the last
    /// buffer).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of pool capacity held (`buffers * buf_size`) — the
    /// fragmentation-free accounting unit.
    pub fn held_bytes(&self) -> usize {
        self.bufs.len() * self.bufs.first().map_or(0, |b| b.len())
    }

    /// Unused tail bytes (the Figure-3B trade-off, reported by stats).
    pub fn waste(&self) -> usize {
        self.held_bytes() - self.len
    }

    pub fn num_buffers(&self) -> usize {
        self.bufs.len()
    }

    /// Reassemble the logical bytes (device upload / network send path).
    pub fn read(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len);
        let mut remaining = self.len;
        for b in &self.bufs {
            let n = remaining.min(b.len());
            out.extend_from_slice(&b.as_slice()[..n]);
            remaining -= n;
        }
        out
    }

    /// The logical bytes as per-buffer slices (vectored network send
    /// and per-chunk positional spill writes).
    pub fn chunk_slices(&self) -> Vec<&[u8]> {
        let mut out = Vec::with_capacity(self.bufs.len());
        let mut remaining = self.len;
        for b in &self.bufs {
            let n = remaining.min(b.len());
            if n == 0 {
                break;
            }
            out.push(&b.as_slice()[..n]);
            remaining -= n;
        }
        out
    }

    /// Visit the logical bytes buffer-by-buffer without reassembling.
    pub fn for_each_chunk(&self, mut f: impl FnMut(&[u8])) {
        for c in self.chunk_slices() {
            f(c);
        }
    }
}

impl std::fmt::Debug for PinnedSlab {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PinnedSlab({} bytes in {} bufs, {} waste)",
            self.len,
            self.bufs.len(),
            self.waste()
        )
    }
}

/// Incremental slab builder: acquire-as-you-fill, so producers (object
/// stores, decompressors, sockets) write straight into pinned buffers
/// instead of returning a heap `Vec` that gets copied in afterwards.
pub struct SlabWriter {
    pool: PinnedPool,
    bufs: Vec<PinnedBuf>,
    len: usize,
    /// Whether fills count toward the pool's `bounce_bytes`. True for
    /// staging copies (bytes entering the pool from heap, socket, or
    /// disk); false for pool-to-pool transforms (compressing a slab for
    /// the wire, decompressing a received slab), whose bytes were
    /// already counted on entry.
    count_bounce: bool,
}

impl SlabWriter {
    /// An empty writer; buffers are acquired lazily as bytes arrive.
    pub fn new(pool: &PinnedPool) -> SlabWriter {
        SlabWriter { pool: pool.clone(), bufs: Vec::new(), len: 0, count_bounce: true }
    }

    /// Set whether this writer's fills count as bounce copies (builder
    /// style; default true). Pass `false` when the source bytes are
    /// already pool-resident, so `pinned.bounce_bytes` keeps meaning
    /// "bytes that entered the pool" rather than double-counting
    /// codec transforms.
    pub fn count_bounce(mut self, count: bool) -> SlabWriter {
        self.count_bounce = count;
        self
    }

    /// A writer with every buffer `cap` bytes will need acquired up
    /// front (all-or-nothing). Callers filling from a consumable source
    /// (socket, stream decoder) use this so a dry pool fails *before*
    /// the source has been touched, and raises host pressure for the
    /// shortfall like [`PinnedSlab::write`].
    pub fn with_capacity(pool: &PinnedPool, cap: usize) -> Result<SlabWriter> {
        let mut w = SlabWriter::new(pool);
        w.reserve(cap)?;
        Ok(w)
    }

    /// [`SlabWriter::with_capacity`] whose shortfall does **not** raise
    /// host pressure (see [`PinnedPool::try_acquire_quiet`]): for
    /// callers with a mandatory heap fallback that must not re-arm the
    /// pressure epoch they themselves act on.
    pub fn with_capacity_quiet(pool: &PinnedPool, cap: usize) -> Result<SlabWriter> {
        let mut w = SlabWriter::new(pool);
        w.reserve_with(cap, false)?;
        Ok(w)
    }

    /// Ensure buffers exist for a total of `cap` bytes (at least one —
    /// an empty slab still occupies a buffer, as in Figure 3B).
    pub fn reserve(&mut self, cap: usize) -> Result<()> {
        self.reserve_with(cap, true)
    }

    fn reserve_with(&mut self, cap: usize, raise: bool) -> Result<()> {
        let bs = self.pool.buf_size();
        let need = cap.div_ceil(bs).max(1);
        if need > self.bufs.len() {
            let extra = need - self.bufs.len();
            let avail = self.pool.free_buffers();
            if extra > avail {
                // Raise pressure only for satisfiable shortfalls: a
                // request larger than the whole pool can never be met
                // by demoting host data, so signaling it would only
                // trigger futile spill storms (oversized payloads take
                // the heap fallback and move on).
                if raise && need <= self.pool.total_buffers() {
                    self.pool.raise_pressure((extra - avail) * bs);
                }
                return Err(Error::PinnedExhausted { requested: extra, available: avail });
            }
            for _ in 0..extra {
                let buf = if raise {
                    self.pool.try_acquire()
                } else {
                    self.pool.try_acquire_quiet()
                }?;
                self.bufs.push(buf);
            }
        }
        Ok(())
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append bytes, acquiring buffers as the fill crosses boundaries.
    /// On pool exhaustion the bytes written so far stay intact (the
    /// caller may fall back to heap or retry after pressure relief).
    pub fn write_bytes(&mut self, data: &[u8]) -> Result<()> {
        let bs = self.pool.buf_size();
        let mut data = data;
        while !data.is_empty() {
            let buf_idx = self.len / bs;
            if buf_idx == self.bufs.len() {
                self.bufs.push(self.pool.try_acquire()?);
            }
            let off = self.len % bs;
            let n = (bs - off).min(data.len());
            self.bufs[buf_idx].as_mut_slice()[off..off + n].copy_from_slice(&data[..n]);
            self.len += n;
            if self.count_bounce {
                self.pool.note_bounce(n);
            }
            data = &data[n..];
        }
        Ok(())
    }

    /// Fill exactly `len` more bytes via positional reads: `read` is
    /// called once per buffer segment with (offset-within-fill, dest).
    /// The spill-reload and socket-receive paths use this to land bytes
    /// in pinned memory without an intermediate heap `Vec`.
    pub fn fill_positional(
        &mut self,
        len: usize,
        mut read: impl FnMut(u64, &mut [u8]) -> std::io::Result<()>,
    ) -> Result<()> {
        let bs = self.pool.buf_size();
        self.reserve(self.len + len)?;
        let mut remaining = len;
        let mut src_off = 0u64;
        while remaining > 0 {
            let buf_idx = self.len / bs;
            let off = self.len % bs;
            let n = (bs - off).min(remaining);
            read(src_off, &mut self.bufs[buf_idx].as_mut_slice()[off..off + n])?;
            self.len += n;
            if self.count_bounce {
                self.pool.note_bounce(n);
            }
            remaining -= n;
            src_off += n as u64;
        }
        Ok(())
    }

    /// Seal the slab. Unused buffers beyond the fill (over-reserved
    /// capacity) return to the pool here; the final buffer's tail is
    /// the accounted Figure-3B waste.
    pub fn finish(mut self) -> PinnedSlab {
        let bs = self.pool.buf_size();
        let used = self.len.div_ceil(bs).max(1).min(self.bufs.len());
        self.bufs.truncate(used); // drop releases over-reservation
        let slab = PinnedSlab { bufs: self.bufs, len: self.len };
        self.pool.note_waste(slab.waste());
        slab
    }
}

impl std::io::Write for SlabWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.write_bytes(buf).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::OutOfMemory, e.to_string())
        })?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// A cheap shared view of part of a slab: the coalesced-fetch block is
/// fetched once and its per-column pages are slices of it; the network
/// receive path strips the codec prelude by slicing. Dropping the last
/// slice of a slab returns its buffers to the pool.
#[derive(Clone)]
pub struct SlabSlice {
    slab: Arc<PinnedSlab>,
    offset: usize,
    len: usize,
}

impl SlabSlice {
    /// View of an entire slab.
    pub fn whole(slab: PinnedSlab) -> SlabSlice {
        let len = slab.len();
        SlabSlice { slab: Arc::new(slab), offset: 0, len }
    }

    pub fn new(slab: Arc<PinnedSlab>, offset: usize, len: usize) -> SlabSlice {
        assert!(
            offset + len <= slab.len(),
            "slice {offset}+{len} beyond slab len {}",
            slab.len()
        );
        SlabSlice { slab, offset, len }
    }

    /// Sub-slice (relative to this slice).
    pub fn slice(&self, offset: usize, len: usize) -> SlabSlice {
        assert!(offset + len <= self.len, "sub-slice {offset}+{len} beyond {}", self.len);
        SlabSlice { slab: self.slab.clone(), offset: self.offset + offset, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pool bytes held alive by the underlying slab (shared across all
    /// slices of it).
    pub fn held_bytes(&self) -> usize {
        self.slab.held_bytes()
    }

    /// True when this view is the slab's only owner (no sibling slices
    /// alive) — the condition under which a Batch Holder may adopt it
    /// and account its bytes as exclusively-held pool memory. Sibling
    /// views only ever *drop* after a fan-out, so a `true` here is
    /// stable; a `false` is conservative.
    pub fn is_exclusive(&self) -> bool {
        Arc::strong_count(&self.slab) == 1
    }

    /// The slice's bytes as per-buffer chunks (vectored I/O).
    pub fn chunks(&self) -> Vec<&[u8]> {
        if self.len == 0 {
            return Vec::new();
        }
        let bs = self.slab.bufs[0].len();
        let mut out = Vec::new();
        let mut pos = self.offset;
        let end = self.offset + self.len;
        while pos < end {
            let bi = pos / bs;
            let off = pos % bs;
            let n = (bs - off).min(end - pos);
            out.push(&self.slab.bufs[bi].as_slice()[off..off + n]);
            pos += n;
        }
        out
    }

    /// Reassembled bytes (device upload / decode staging).
    pub fn to_vec(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len);
        for c in self.chunks() {
            out.extend_from_slice(c);
        }
        out
    }

    /// Borrow the bytes contiguously when the slice lies within one
    /// buffer; reassemble (copy) only when it spans a boundary.
    pub fn contiguous(&self) -> Cow<'_, [u8]> {
        if self.len == 0 {
            return Cow::Borrowed(&[]);
        }
        let bs = self.slab.bufs[0].len();
        let first = self.offset / bs;
        let last = (self.offset + self.len - 1) / bs;
        if first == last {
            let off = self.offset % bs;
            Cow::Borrowed(&self.slab.bufs[first].as_slice()[off..off + self.len])
        } else {
            Cow::Owned(self.to_vec())
        }
    }
}

impl std::fmt::Debug for SlabSlice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SlabSlice({}+{} of {:?})", self.offset, self.len, self.slab)
    }
}

/// Byte container used across the data plane: slab-backed when the
/// bounce pool had room, heap when it was dry or absent (the mandatory
/// fallback — pool exhaustion degrades throughput, never correctness).
#[derive(Clone)]
pub enum StagedBytes {
    Pinned(SlabSlice),
    Heap(Vec<u8>),
}

impl StagedBytes {
    pub fn len(&self) -> usize {
        match self {
            StagedBytes::Pinned(s) => s.len(),
            StagedBytes::Heap(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_pinned(&self) -> bool {
        matches!(self, StagedBytes::Pinned(_))
    }

    /// The bytes as vectored chunks (no reassembly).
    pub fn chunks(&self) -> Vec<&[u8]> {
        match self {
            StagedBytes::Pinned(s) => s.chunks(),
            StagedBytes::Heap(v) if v.is_empty() => Vec::new(),
            StagedBytes::Heap(v) => vec![v.as_slice()],
        }
    }

    /// Contiguous view; copies only for multi-buffer slab slices.
    pub fn contiguous(&self) -> Cow<'_, [u8]> {
        match self {
            StagedBytes::Pinned(s) => s.contiguous(),
            StagedBytes::Heap(v) => Cow::Borrowed(v),
        }
    }

    /// Own the bytes as a heap `Vec` (free for `Heap`).
    pub fn into_vec(self) -> Vec<u8> {
        match self {
            StagedBytes::Pinned(s) => s.to_vec(),
            StagedBytes::Heap(v) => v,
        }
    }
}

impl From<Vec<u8>> for StagedBytes {
    fn from(v: Vec<u8>) -> StagedBytes {
        StagedBytes::Heap(v)
    }
}

impl PartialEq for StagedBytes {
    fn eq(&self, other: &Self) -> bool {
        *self.contiguous() == *other.contiguous()
    }
}

impl PartialEq<Vec<u8>> for StagedBytes {
    fn eq(&self, other: &Vec<u8>) -> bool {
        *self.contiguous() == other[..]
    }
}

impl std::fmt::Debug for StagedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StagedBytes::Pinned(s) => write!(f, "StagedBytes::Pinned({} bytes)", s.len()),
            StagedBytes::Heap(v) => write!(f, "StagedBytes::Heap({} bytes)", v.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let p = PinnedPool::new(1024, 4).unwrap();
        assert_eq!(p.free_buffers(), 4);
        let a = p.try_acquire().unwrap();
        let b = p.try_acquire().unwrap();
        assert_eq!(p.free_buffers(), 2);
        drop(a);
        assert_eq!(p.free_buffers(), 3);
        drop(b);
        assert_eq!(p.free_buffers(), 4);
    }

    #[test]
    fn exhaustion_is_typed_error() {
        let p = PinnedPool::new(64, 1).unwrap();
        let _a = p.try_acquire().unwrap();
        assert!(matches!(
            p.try_acquire(),
            Err(Error::PinnedExhausted { .. })
        ));
        assert_eq!(p.exhaustion_count(), 1);
    }

    #[test]
    fn buffers_are_disjoint_and_writable() {
        let p = PinnedPool::new(128, 3).unwrap();
        let mut a = p.try_acquire().unwrap();
        let mut b = p.try_acquire().unwrap();
        a.as_mut_slice().fill(0xAA);
        b.as_mut_slice().fill(0xBB);
        assert!(a.as_slice().iter().all(|&x| x == 0xAA));
        assert!(b.as_slice().iter().all(|&x| x == 0xBB));
    }

    #[test]
    fn slab_roundtrip_spanning_buffers() {
        let p = PinnedPool::new(100, 8).unwrap();
        let data: Vec<u8> = (0..=255u8).cycle().take(350).collect();
        let slab = PinnedSlab::write(&p, &data).unwrap();
        assert_eq!(slab.num_buffers(), 4); // 350 / 100 -> 4 buffers
        assert_eq!(slab.len(), 350);
        assert_eq!(slab.waste(), 50);
        assert_eq!(slab.read(), data);
        drop(slab);
        assert_eq!(p.free_buffers(), 8);
    }

    #[test]
    fn slab_empty_data_takes_one_buffer() {
        let p = PinnedPool::new(64, 2).unwrap();
        let slab = PinnedSlab::write(&p, &[]).unwrap();
        assert_eq!(slab.len(), 0);
        assert!(slab.is_empty());
        assert_eq!(slab.read(), Vec::<u8>::new());
    }

    #[test]
    fn slab_fails_cleanly_when_pool_too_small() {
        let p = PinnedPool::new(64, 2).unwrap();
        let data = vec![1u8; 64 * 3];
        match PinnedSlab::write(&p, &data) {
            Err(Error::PinnedExhausted { requested, available }) => {
                assert_eq!((requested, available), (3, 2));
            }
            other => panic!("{other:?}"),
        }
        // nothing leaked
        assert_eq!(p.free_buffers(), 2);
    }

    #[test]
    fn chunk_iteration_matches_read() {
        let p = PinnedPool::new(50, 4).unwrap();
        let data: Vec<u8> = (0..120u8).collect();
        let slab = PinnedSlab::write(&p, &data).unwrap();
        let mut got = Vec::new();
        slab.for_each_chunk(|c| got.extend_from_slice(c));
        assert_eq!(got, data);
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let p = PinnedPool::new(32, 1).unwrap();
        let held = p.try_acquire().unwrap();
        let p2 = p.clone();
        let h = std::thread::spawn(move || {
            p2.acquire_timeout(std::time::Duration::from_secs(2)).is_ok()
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        drop(held);
        assert!(h.join().unwrap());
    }

    #[test]
    fn timeout_expires_when_pool_stays_dry() {
        let p = PinnedPool::new(32, 1).unwrap();
        let _held = p.try_acquire().unwrap();
        let r = p.acquire_timeout(std::time::Duration::from_millis(30));
        assert!(matches!(r, Err(Error::PinnedExhausted { .. })));
    }

    #[test]
    fn slab_writer_incremental_fill() {
        let p = PinnedPool::new(32, 4).unwrap();
        let mut w = SlabWriter::new(&p);
        assert_eq!(p.free_buffers(), 4, "lazy: nothing acquired yet");
        w.write_bytes(&[1u8; 20]).unwrap();
        assert_eq!(p.free_buffers(), 3);
        w.write_bytes(&[2u8; 30]).unwrap(); // crosses into buffer 2
        w.write_bytes(&[3u8; 50]).unwrap(); // and buffers 3..4
        assert_eq!(w.len(), 100);
        let slab = w.finish();
        assert_eq!(slab.num_buffers(), 4);
        let mut want = vec![1u8; 20];
        want.extend_from_slice(&[2; 30]);
        want.extend_from_slice(&[3; 50]);
        assert_eq!(slab.read(), want);
        assert_eq!(p.bounce_bytes(), 100);
        assert_eq!(p.waste_bytes(), 28, "4x32 - 100");
    }

    #[test]
    fn slab_writer_io_write_and_overreserve() {
        use std::io::Write;
        let p = PinnedPool::new(16, 8).unwrap();
        let mut w = SlabWriter::with_capacity(&p, 100).unwrap();
        assert_eq!(p.free_buffers(), 1, "7 buffers reserved up front");
        w.write_all(&[9u8; 40]).unwrap();
        let slab = w.finish();
        assert_eq!(slab.len(), 40);
        assert_eq!(slab.num_buffers(), 3, "over-reservation released");
        assert_eq!(p.free_buffers(), 5);
    }

    #[test]
    fn from_reader_lands_exact_bytes() {
        let p = PinnedPool::new(16, 8).unwrap();
        let data: Vec<u8> = (0..100u8).collect();
        let mut cur = std::io::Cursor::new(data.clone());
        let slab = PinnedSlab::from_reader(&p, &mut cur, 60).unwrap();
        assert_eq!(slab.read(), &data[..60]);
        assert_eq!(cur.position(), 60, "reads exactly len");
        // a dry pool fails before consuming the reader
        let _hold: Vec<_> = (0..p.free_buffers()).map(|_| p.try_acquire().unwrap()).collect();
        let before = cur.position();
        assert!(matches!(
            PinnedSlab::from_reader(&p, &mut cur, 30),
            Err(Error::PinnedExhausted { .. })
        ));
        assert_eq!(cur.position(), before, "reader untouched on exhaustion");
    }

    #[test]
    fn slab_slice_chunks_and_contiguous() {
        let p = PinnedPool::new(10, 8).unwrap();
        let data: Vec<u8> = (0..35u8).collect();
        let slab = PinnedSlab::write(&p, &data).unwrap();
        let whole = SlabSlice::whole(slab);
        assert_eq!(whole.to_vec(), data);
        // a slice within one buffer borrows contiguously
        let inner = whole.slice(11, 8);
        assert!(matches!(inner.contiguous(), std::borrow::Cow::Borrowed(_)));
        assert_eq!(&*inner.contiguous(), &data[11..19]);
        // a boundary-spanning slice reassembles
        let spanning = whole.slice(5, 20);
        assert!(matches!(spanning.contiguous(), std::borrow::Cow::Owned(_)));
        assert_eq!(&*spanning.contiguous(), &data[5..25]);
        assert_eq!(spanning.chunks().len(), 3, "5..10, 10..20, 20..25");
        // slices share the slab: buffers free only when all are dropped
        drop(whole);
        assert!(p.free_buffers() < 8);
        drop(inner);
        drop(spanning);
        assert_eq!(p.free_buffers(), 8);
    }

    #[test]
    fn concurrent_slab_writers_under_exhaustion() {
        // Many writers fighting over a pool smaller than their combined
        // demand: every fill either completes correctly or fails with
        // the typed exhaustion error; nothing leaks, nothing corrupts.
        let p = PinnedPool::new(64, 8).unwrap();
        let hs: Vec<_> = (0..6u8)
            .map(|t| {
                let p = p.clone();
                std::thread::spawn(move || {
                    let mut ok = 0u32;
                    let mut dry = 0u32;
                    for i in 0..200u32 {
                        let payload = vec![t.wrapping_add(i as u8); 150]; // 3 buffers
                        let mut w = SlabWriter::new(&p);
                        match w.write_bytes(&payload) {
                            Ok(()) => {
                                let slab = w.finish();
                                assert_eq!(slab.read(), payload, "thread {t} iter {i}");
                                ok += 1;
                            }
                            Err(Error::PinnedExhausted { .. }) => dry += 1,
                            Err(e) => panic!("unexpected: {e}"),
                        }
                    }
                    (ok, dry)
                })
            })
            .collect();
        let mut total_ok = 0;
        for h in hs {
            let (ok, _) = h.join().unwrap();
            total_ok += ok;
        }
        assert!(total_ok > 0, "some fills must succeed");
        assert_eq!(p.free_buffers(), 8, "no buffers leaked under contention");
    }

    #[test]
    fn pinned_buf_is_empty_reflects_len() {
        let p = PinnedPool::new(128, 1).unwrap();
        let b = p.try_acquire().unwrap();
        assert_eq!(b.len(), 128);
        assert!(!b.is_empty(), "fixed-size buffers are never zero-length");
    }

    #[test]
    fn transform_writer_skips_bounce_accounting() {
        let p = PinnedPool::new(32, 8).unwrap();
        let mut staging = SlabWriter::new(&p);
        staging.write_bytes(&[1u8; 50]).unwrap();
        let s1 = staging.finish();
        assert_eq!(p.bounce_bytes(), 50, "staging copies count");
        let mut transform = SlabWriter::new(&p).count_bounce(false);
        transform.write_bytes(&s1.read()).unwrap();
        let s2 = transform.finish();
        assert_eq!(s2.read(), s1.read());
        assert_eq!(p.bounce_bytes(), 50, "pool-to-pool transforms do not");
        assert_eq!(p.codec_heap_fallback_bytes(), 0);
        p.note_codec_fallback(123);
        assert_eq!(p.codec_heap_fallback_bytes(), 123);
    }

    #[test]
    fn exhaustion_raises_host_pressure() {
        let p = PinnedPool::new(64, 4).unwrap();
        let ev = PressureEvent::new();
        p.install_pressure(ev.clone());
        let held: Vec<_> = (0..4).map(|_| p.try_acquire().unwrap()).collect();
        assert!(p.try_acquire().is_err());
        assert_eq!(ev.take().host_need, 64);
        // the quiet variants fail without raising (shuffle staging path)
        assert!(p.try_acquire_quiet().is_err());
        assert!(SlabWriter::with_capacity_quiet(&p, 128).is_err());
        assert_eq!(ev.take().host_need, 0, "quiet shortfalls must not raise");
        assert_eq!(ev.memory_raise_count(), 1, "only the loud failure raised");
        // slab-level exhaustion raises the full (satisfiable) shortfall
        assert!(PinnedSlab::write(&p, &[0u8; 200]).is_err());
        assert_eq!(ev.take().host_need, 4 * 64);
        // a request bigger than the whole pool must NOT raise pressure:
        // no amount of demotion can ever serve it
        drop(held);
        assert!(PinnedSlab::write(&p, &[0u8; 64 * 5]).is_err());
        assert_eq!(ev.take().host_need, 0);
    }
}
