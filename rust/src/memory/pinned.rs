//! Fixed-size page-locked host buffer pool (§3.4, Figure 3B).
//!
//! "Large amounts of page-locked memory are slow to allocate ... the
//! engine has a pool of pre-allocated fixed-size page-locked buffers
//! which is allocated during engine initialization. Data from all
//! columns is placed into these buffers, allowing a single column's
//! contents to overlap multiple buffers. This approach provides
//! resilience to memory fragmentation at the cost of a small unused
//! block of memory per batch."
//!
//! The buffers here are real: backed by one contiguous region allocated
//! once at pool construction and `mlock(2)`ed when the RLIMIT permits
//! (gracefully degrading to plain memory otherwise — the *layout*
//! discipline, which is what the paper's Figure 3B is about, is
//! identical either way). The same pool doubles as the network bounce
//! buffer and pre-load staging area, exactly as in §3.4.

use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::memory::pressure::PressureEvent;
use crate::{Error, Result};

/// Shared pool of fixed-size buffers carved from one pinned region.
#[derive(Clone)]
pub struct PinnedPool {
    inner: Arc<Inner>,
}

struct Inner {
    buf_size: usize,
    /// Base of the contiguous region (never reallocated).
    region: Region,
    free: Mutex<Vec<u32>>,
    available: Condvar,
    total: usize,
    mlocked: bool,
    acquires: std::sync::atomic::AtomicU64,
    exhaustions: std::sync::atomic::AtomicU64,
    /// Raised with host-tier pressure whenever the pool runs dry, so
    /// the Data-Movement executor demotes host data to disk (§3.4: the
    /// pool doubles as bounce buffer and staging area — exhaustion here
    /// stalls network receives and pre-loads alike).
    pressure: OnceLock<Arc<PressureEvent>>,
}

/// One contiguous, optionally mlocked allocation.
struct Region {
    ptr: *mut u8,
    len: usize,
}

// The region is only accessed through disjoint per-buffer slices handed
// out under the free-list lock; the raw pointer itself is immutable.
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

impl Drop for Region {
    fn drop(&mut self) {
        unsafe {
            libc::munlock(self.ptr as *const libc::c_void, self.len);
            let layout = std::alloc::Layout::from_size_align(self.len, 4096).unwrap();
            std::alloc::dealloc(self.ptr, layout);
        }
    }
}

impl PinnedPool {
    /// Allocate `buffers` buffers of `buf_size` bytes each, up front.
    /// Attempts to `mlock` the region; falls back to unpinned memory if
    /// the rlimit forbids it (check [`PinnedPool::is_mlocked`]).
    pub fn new(buf_size: usize, buffers: usize) -> Result<Self> {
        assert!(buf_size > 0 && buffers > 0);
        let len = buf_size * buffers;
        let layout = std::alloc::Layout::from_size_align(len, 4096)
            .map_err(|e| Error::internal(format!("pinned layout: {e}")))?;
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) };
        if ptr.is_null() {
            return Err(Error::internal("pinned pool allocation failed"));
        }
        let mlocked =
            unsafe { libc::mlock(ptr as *const libc::c_void, len) == 0 };
        Ok(PinnedPool {
            inner: Arc::new(Inner {
                buf_size,
                region: Region { ptr, len },
                free: Mutex::new((0..buffers as u32).rev().collect()),
                available: Condvar::new(),
                total: buffers,
                mlocked,
                acquires: Default::default(),
                exhaustions: Default::default(),
                pressure: OnceLock::new(),
            }),
        })
    }

    /// Install the shared pressure event (one-shot; later installs are
    /// ignored).
    pub fn install_pressure(&self, event: Arc<PressureEvent>) {
        let _ = self.inner.pressure.set(event);
    }

    fn raise_pressure(&self, bytes: usize) {
        if let Some(ev) = self.inner.pressure.get() {
            ev.raise_host(bytes);
        }
    }

    pub fn buf_size(&self) -> usize {
        self.inner.buf_size
    }

    pub fn total_buffers(&self) -> usize {
        self.inner.total
    }

    pub fn free_buffers(&self) -> usize {
        self.inner.free.lock().unwrap().len()
    }

    pub fn is_mlocked(&self) -> bool {
        self.inner.mlocked
    }

    pub fn acquire_count(&self) -> u64 {
        self.inner.acquires.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn exhaustion_count(&self) -> u64 {
        self.inner.exhaustions.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Take one buffer, failing immediately if the pool is dry (the
    /// caller decides whether to spill or wait).
    pub fn try_acquire(&self) -> Result<PinnedBuf> {
        let mut free = self.inner.free.lock().unwrap();
        match free.pop() {
            Some(idx) => {
                self.inner
                    .acquires
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(PinnedBuf { pool: self.clone(), idx })
            }
            None => {
                self.inner
                    .exhaustions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.raise_pressure(self.inner.buf_size);
                Err(Error::PinnedExhausted { requested: 1, available: 0 })
            }
        }
    }

    /// Take one buffer, blocking until one frees up or `timeout`. Dry
    /// pool raises host pressure before parking so the Data-Movement
    /// executor can demote host data and free buffers while we wait.
    pub fn acquire_timeout(&self, timeout: std::time::Duration) -> Result<PinnedBuf> {
        let deadline = std::time::Instant::now() + timeout;
        let mut free = self.inner.free.lock().unwrap();
        loop {
            if let Some(idx) = free.pop() {
                self.inner
                    .acquires
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Ok(PinnedBuf { pool: self.clone(), idx });
            }
            self.raise_pressure(self.inner.buf_size);
            let now = std::time::Instant::now();
            if now >= deadline {
                self.inner
                    .exhaustions
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Err(Error::PinnedExhausted { requested: 1, available: 0 });
            }
            let (guard, _) = self
                .inner
                .available
                .wait_timeout(free, deadline - now)
                .unwrap();
            free = guard;
        }
    }

    fn release(&self, idx: u32) {
        let mut free = self.inner.free.lock().unwrap();
        debug_assert!(!free.contains(&idx), "double release of pinned buf {idx}");
        free.push(idx);
        drop(free);
        self.inner.available.notify_one();
    }

    fn slice_ptr(&self, idx: u32) -> *mut u8 {
        debug_assert!((idx as usize) < self.inner.total);
        unsafe { self.inner.region.ptr.add(idx as usize * self.inner.buf_size) }
    }
}

/// Exclusive handle to one fixed-size buffer; returns to the pool on
/// drop.
pub struct PinnedBuf {
    pool: PinnedPool,
    idx: u32,
}

impl PinnedBuf {
    pub fn len(&self) -> usize {
        self.pool.inner.buf_size
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.pool.slice_ptr(self.idx), self.len()) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        unsafe {
            std::slice::from_raw_parts_mut(self.pool.slice_ptr(self.idx), self.len())
        }
    }
}

impl Drop for PinnedBuf {
    fn drop(&mut self) {
        self.pool.release(self.idx);
    }
}

impl std::fmt::Debug for PinnedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PinnedBuf#{}({} bytes)", self.idx, self.len())
    }
}

/// A logical byte region spanning one or more pool buffers — the Figure
/// 3B layout, where "a single column's contents [can] overlap multiple
/// buffers" and the final buffer's tail is "a small unused block".
pub struct PinnedSlab {
    bufs: Vec<PinnedBuf>,
    len: usize,
}

impl PinnedSlab {
    /// Copy `data` into freshly acquired pool buffers.
    pub fn write(pool: &PinnedPool, data: &[u8]) -> Result<PinnedSlab> {
        let bs = pool.buf_size();
        let need = data.len().div_ceil(bs).max(1);
        let avail = pool.free_buffers();
        if need > avail {
            pool.raise_pressure((need - avail) * bs);
            return Err(Error::PinnedExhausted { requested: need, available: avail });
        }
        let mut bufs = Vec::with_capacity(need);
        for chunk_idx in 0..need {
            let mut b = pool.try_acquire()?;
            let off = chunk_idx * bs;
            let n = bs.min(data.len() - off.min(data.len()));
            if n > 0 {
                b.as_mut_slice()[..n].copy_from_slice(&data[off..off + n]);
            }
            bufs.push(b);
        }
        Ok(PinnedSlab { bufs, len: data.len() })
    }

    /// Logical byte length (excludes the unused tail of the last
    /// buffer).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of pool capacity held (`buffers * buf_size`) — the
    /// fragmentation-free accounting unit.
    pub fn held_bytes(&self) -> usize {
        self.bufs.len() * self.bufs.first().map_or(0, |b| b.len())
    }

    /// Unused tail bytes (the Figure-3B trade-off, reported by stats).
    pub fn waste(&self) -> usize {
        self.held_bytes() - self.len
    }

    pub fn num_buffers(&self) -> usize {
        self.bufs.len()
    }

    /// Reassemble the logical bytes (device upload / network send path).
    pub fn read(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len);
        let mut remaining = self.len;
        for b in &self.bufs {
            let n = remaining.min(b.len());
            out.extend_from_slice(&b.as_slice()[..n]);
            remaining -= n;
        }
        out
    }

    /// Visit the logical bytes buffer-by-buffer without reassembling
    /// (zero-copy scatter path for the network executor).
    pub fn for_each_chunk(&self, mut f: impl FnMut(&[u8])) {
        let mut remaining = self.len;
        for b in &self.bufs {
            let n = remaining.min(b.len());
            if n == 0 {
                break;
            }
            f(&b.as_slice()[..n]);
            remaining -= n;
        }
    }
}

impl std::fmt::Debug for PinnedSlab {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PinnedSlab({} bytes in {} bufs, {} waste)",
            self.len,
            self.bufs.len(),
            self.waste()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let p = PinnedPool::new(1024, 4).unwrap();
        assert_eq!(p.free_buffers(), 4);
        let a = p.try_acquire().unwrap();
        let b = p.try_acquire().unwrap();
        assert_eq!(p.free_buffers(), 2);
        drop(a);
        assert_eq!(p.free_buffers(), 3);
        drop(b);
        assert_eq!(p.free_buffers(), 4);
    }

    #[test]
    fn exhaustion_is_typed_error() {
        let p = PinnedPool::new(64, 1).unwrap();
        let _a = p.try_acquire().unwrap();
        assert!(matches!(
            p.try_acquire(),
            Err(Error::PinnedExhausted { .. })
        ));
        assert_eq!(p.exhaustion_count(), 1);
    }

    #[test]
    fn buffers_are_disjoint_and_writable() {
        let p = PinnedPool::new(128, 3).unwrap();
        let mut a = p.try_acquire().unwrap();
        let mut b = p.try_acquire().unwrap();
        a.as_mut_slice().fill(0xAA);
        b.as_mut_slice().fill(0xBB);
        assert!(a.as_slice().iter().all(|&x| x == 0xAA));
        assert!(b.as_slice().iter().all(|&x| x == 0xBB));
    }

    #[test]
    fn slab_roundtrip_spanning_buffers() {
        let p = PinnedPool::new(100, 8).unwrap();
        let data: Vec<u8> = (0..=255u8).cycle().take(350).collect();
        let slab = PinnedSlab::write(&p, &data).unwrap();
        assert_eq!(slab.num_buffers(), 4); // 350 / 100 -> 4 buffers
        assert_eq!(slab.len(), 350);
        assert_eq!(slab.waste(), 50);
        assert_eq!(slab.read(), data);
        drop(slab);
        assert_eq!(p.free_buffers(), 8);
    }

    #[test]
    fn slab_empty_data_takes_one_buffer() {
        let p = PinnedPool::new(64, 2).unwrap();
        let slab = PinnedSlab::write(&p, &[]).unwrap();
        assert_eq!(slab.len(), 0);
        assert!(slab.is_empty());
        assert_eq!(slab.read(), Vec::<u8>::new());
    }

    #[test]
    fn slab_fails_cleanly_when_pool_too_small() {
        let p = PinnedPool::new(64, 2).unwrap();
        let data = vec![1u8; 64 * 3];
        match PinnedSlab::write(&p, &data) {
            Err(Error::PinnedExhausted { requested, available }) => {
                assert_eq!((requested, available), (3, 2));
            }
            other => panic!("{other:?}"),
        }
        // nothing leaked
        assert_eq!(p.free_buffers(), 2);
    }

    #[test]
    fn chunk_iteration_matches_read() {
        let p = PinnedPool::new(50, 4).unwrap();
        let data: Vec<u8> = (0..120u8).collect();
        let slab = PinnedSlab::write(&p, &data).unwrap();
        let mut got = Vec::new();
        slab.for_each_chunk(|c| got.extend_from_slice(c));
        assert_eq!(got, data);
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let p = PinnedPool::new(32, 1).unwrap();
        let held = p.try_acquire().unwrap();
        let p2 = p.clone();
        let h = std::thread::spawn(move || {
            p2.acquire_timeout(std::time::Duration::from_secs(2)).is_ok()
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        drop(held);
        assert!(h.join().unwrap());
    }

    #[test]
    fn timeout_expires_when_pool_stays_dry() {
        let p = PinnedPool::new(32, 1).unwrap();
        let _held = p.try_acquire().unwrap();
        let r = p.acquire_timeout(std::time::Duration::from_millis(30));
        assert!(matches!(r, Err(Error::PinnedExhausted { .. })));
    }

    #[test]
    fn exhaustion_raises_host_pressure() {
        let p = PinnedPool::new(64, 1).unwrap();
        let ev = PressureEvent::new();
        p.install_pressure(ev.clone());
        let _held = p.try_acquire().unwrap();
        assert!(p.try_acquire().is_err());
        assert_eq!(ev.take().host_need, 64);
        // slab-level exhaustion raises the full shortfall
        assert!(PinnedSlab::write(&p, &[0u8; 200]).is_err());
        assert_eq!(ev.take().host_need, 4 * 64);
    }
}
