//! Datasources: how scan tasks turn footer metadata into fetched column
//! pages (§3.3.4).
//!
//! Two implementations reproduce the Fig-4 F→G ablation:
//!
//! * [`GenericDatasource`] — the "Arrow S3 Datasource" baseline: one
//!   store request per column chunk, no footer cache, no coalescing.
//! * [`CustomObjectStoreDatasource`] — the paper's custom datasource:
//!   footer caching, *request coalescing* ("coalesces multiple reads
//!   into single requests to increase throughput"), and staging through
//!   the fixed-size page-locked buffer pool (bounce buffers, §3.4).
//!
//! Both also serve the Byte-Range Pre-loader (§3.3.3), which plans
//! merged ranges via [`plan_ranges`] and fetches them ahead of compute.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::fault::{self, RetryPolicy};
use crate::memory::{PinnedPool, SlabSlice, SlabWriter, StagedBytes};
use crate::metrics::Metrics;
use crate::storage::format::{FileFooter, RowGroupMeta};
use crate::storage::object_store::ObjectStore;
use crate::Result;

/// Monotone datasource versions: every mutation of a table's objects
/// bumps a global counter and stamps the table with it. Consumers that
/// cache anything derived from stored bytes (the gateway's serving
/// caches, the custom datasource's footer cache) snapshot versions at
/// fill time and compare at serve time — a mismatch means the bytes
/// under the entry changed and the entry must be dropped. Versions only
/// grow, so a stale reader can never be fooled by an ABA pattern.
#[derive(Clone, Default)]
pub struct SourceVersion {
    inner: Arc<VersionInner>,
}

#[derive(Default)]
struct VersionInner {
    global: AtomicU64,
    tables: Mutex<HashMap<String, u64>>,
}

impl SourceVersion {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a mutation of `table`: advance the global clock and stamp
    /// the table with the new value. Returns the stamp.
    pub fn bump(&self, table: &str) -> u64 {
        let v = self.inner.global.fetch_add(1, Ordering::AcqRel) + 1;
        self.inner
            .tables
            .lock()
            .unwrap()
            .insert(table.to_string(), v);
        v
    }

    /// The global mutation clock (0 = nothing ever written).
    pub fn global(&self) -> u64 {
        self.inner.global.load(Ordering::Acquire)
    }

    /// The last stamp of `table` (0 = never mutated).
    pub fn of(&self, table: &str) -> u64 {
        self.inner
            .tables
            .lock()
            .unwrap()
            .get(table)
            .copied()
            .unwrap_or(0)
    }

    /// Version stamps for a set of tables, for cache-entry validation.
    pub fn snapshot(&self, tables: &[String]) -> Vec<(String, u64)> {
        tables.iter().map(|t| (t.clone(), self.of(t))).collect()
    }
}

/// A contiguous byte range within one object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ByteRange {
    pub offset: u64,
    pub len: u64,
}

impl ByteRange {
    pub fn end(&self) -> u64 {
        self.offset + self.len
    }
}

/// Merge sorted ranges whose gap is at most `max_gap` bytes — the
/// §3.3.3 coalescing rule ("sufficiently close byte ranges are then
/// merged to reduce the total number of read operations"). Returns the
/// merged ranges; over-read (gap) bytes are the cost traded for fewer
/// requests.
pub fn coalesce_ranges(mut ranges: Vec<ByteRange>, max_gap: u64) -> Vec<ByteRange> {
    if ranges.is_empty() {
        return ranges;
    }
    ranges.sort_by_key(|r| r.offset);
    let mut out = Vec::with_capacity(ranges.len());
    let mut cur = ranges[0];
    for r in ranges.into_iter().skip(1) {
        if r.offset <= cur.end() + max_gap {
            let end = cur.end().max(r.end());
            cur.len = end - cur.offset;
        } else {
            out.push(cur);
            cur = r;
        }
    }
    out.push(cur);
    out
}

/// The byte ranges a scan of (`group`, projected `cols`) needs.
pub fn plan_ranges(group: &RowGroupMeta, cols: &[usize]) -> Vec<ByteRange> {
    cols.iter()
        .map(|&c| {
            let ch = &group.chunks[c];
            ByteRange { offset: ch.offset, len: ch.len }
        })
        .collect()
}

/// Fetched pages for one (group, cols) scan unit, in `cols` order.
/// Slab-backed when the fetch staged through the pinned bounce pool
/// (the pages of one coalesced request share that request's slab),
/// heap-backed otherwise — the pre-loader and the compute decode path
/// share the same pool-resident bytes end-to-end.
pub type FetchedPages = Vec<StagedBytes>;

/// How scan tasks read files. Implementations differ in request shape,
/// not in what they return.
pub trait Datasource: Send + Sync {
    /// Fetch and parse a file footer.
    fn footer(&self, key: &str) -> Result<Arc<FileFooter>>;

    /// Fetch the compressed pages for the projected columns of one row
    /// group.
    fn fetch_group(
        &self,
        key: &str,
        footer: &FileFooter,
        group: usize,
        cols: &[usize],
    ) -> Result<FetchedPages>;

    /// Human-readable name (bench reports).
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------
// Generic (baseline) datasource
// ---------------------------------------------------------------------

/// One request per chunk, footer re-fetched every time (the Fig-4 F
/// baseline behaviour of a generic S3 filesystem adapter).
pub struct GenericDatasource {
    store: Arc<dyn ObjectStore>,
    retry: RetryPolicy,
    metrics: OnceLock<Arc<Metrics>>,
}

impl GenericDatasource {
    pub fn new(store: Arc<dyn ObjectStore>) -> Self {
        GenericDatasource {
            store,
            retry: RetryPolicy::default(),
            metrics: OnceLock::new(),
        }
    }

    /// Override the storage-read retry knobs (`storage_retry_limit` /
    /// `storage_backoff_base_ms`) — called at worker bring-up, before
    /// the datasource is shared.
    pub fn set_retry_policy(&mut self, policy: RetryPolicy) {
        self.retry = policy;
    }

    /// Metrics sink for `retry.attempts_total` (first install wins).
    pub fn install_metrics(&self, metrics: Arc<Metrics>) {
        let _ = self.metrics.set(metrics);
    }
}

impl Datasource for GenericDatasource {
    fn footer(&self, key: &str) -> Result<Arc<FileFooter>> {
        fault::with_retry(self.retry, self.metrics.get(), "storage_get", || {
            let file_len = self.store.head(key)?;
            let (toff, tlen) = FileFooter::tail_range(file_len);
            let tail = self.store.get_range(key, toff, tlen)?;
            let (foff, flen) = FileFooter::footer_range(&tail, file_len)?;
            let fbytes = self.store.get_range(key, foff, flen)?;
            Ok(Arc::new(FileFooter::decode(&fbytes)?))
        })
    }

    fn fetch_group(
        &self,
        key: &str,
        footer: &FileFooter,
        group: usize,
        cols: &[usize],
    ) -> Result<FetchedPages> {
        let g = &footer.row_groups[group];
        cols.iter()
            .map(|&c| {
                let ch = &g.chunks[c];
                fault::with_retry(self.retry, self.metrics.get(), "storage_get", || {
                    self.store
                        .get_range(key, ch.offset, ch.len)
                        .map(StagedBytes::Heap)
                })
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "generic"
    }
}

// ---------------------------------------------------------------------
// Custom object-store datasource
// ---------------------------------------------------------------------

/// Stats the benches report (why config G beats F).
#[derive(Clone, Copy, Debug, Default)]
pub struct CustomDsStats {
    pub footer_hits: u64,
    pub footer_misses: u64,
    pub coalesced_requests: u64,
    pub raw_ranges: u64,
    pub overread_bytes: u64,
}

/// Footer cache + range coalescing + pinned bounce buffers.
pub struct CustomObjectStoreDatasource {
    store: Arc<dyn ObjectStore>,
    footers: Mutex<HashMap<String, Arc<FileFooter>>>,
    /// Merge ranges separated by at most this many bytes.
    coalesce_gap: u64,
    /// Stage fetched bytes through the pinned pool when available —
    /// "buffers from the same pool are also utilized as bounce buffers
    /// ... and pre-loading data for table scans" (§3.4).
    pinned: Option<PinnedPool>,
    stats: Mutex<CustomDsStats>,
    retry: RetryPolicy,
    metrics: OnceLock<Arc<Metrics>>,
    /// Store mutation clock (None when the store doesn't track one).
    version: Option<SourceVersion>,
    /// Global clock value the footer cache was filled against; a bump
    /// anywhere flushes the whole cache (footers are cheap to refetch,
    /// correctness is not).
    seen_global: AtomicU64,
}

impl CustomObjectStoreDatasource {
    pub fn new(
        store: Arc<dyn ObjectStore>,
        coalesce_gap: u64,
        pinned: Option<PinnedPool>,
    ) -> Self {
        let version = store.source_version();
        let seen = version.as_ref().map(|v| v.global()).unwrap_or(0);
        CustomObjectStoreDatasource {
            store,
            footers: Mutex::new(HashMap::new()),
            coalesce_gap,
            pinned,
            stats: Mutex::new(CustomDsStats::default()),
            retry: RetryPolicy::default(),
            metrics: OnceLock::new(),
            version,
            seen_global: AtomicU64::new(seen),
        }
    }

    /// Override the storage-read retry knobs (`storage_retry_limit` /
    /// `storage_backoff_base_ms`) — called at worker bring-up, before
    /// the datasource is shared.
    pub fn set_retry_policy(&mut self, policy: RetryPolicy) {
        self.retry = policy;
    }

    /// Metrics sink for `retry.attempts_total` (first install wins).
    pub fn install_metrics(&self, metrics: Arc<Metrics>) {
        let _ = self.metrics.set(metrics);
    }

    /// Drop cached footers if the store advanced past what we cached
    /// against (serving-cache invalidation contract: version bump →
    /// dependent cached state flushes before the next read).
    fn flush_stale_footers(&self) {
        let Some(v) = &self.version else { return };
        let now = v.global();
        if self.seen_global.swap(now, Ordering::AcqRel) != now {
            self.footers.lock().unwrap().clear();
        }
    }

    pub fn stats(&self) -> CustomDsStats {
        *self.stats.lock().unwrap()
    }

    /// Fetch arbitrary coalesced ranges (the Byte-Range Pre-loader path:
    /// it plans ranges across groups itself, then slices pages out).
    ///
    /// Each merged request streams from the store *directly into* a
    /// pinned slab (one bounce copy, in page-locked memory) and the
    /// returned pages are `Arc`-shared slices of that slab — the slab
    /// is never reassembled and the pages never re-copied. When the
    /// pool is dry or absent the fetch degrades to heap buffers (the
    /// read always succeeds; only the bounce is skipped).
    pub fn fetch_ranges(&self, key: &str, ranges: &[ByteRange]) -> Result<FetchedPages> {
        let merged = coalesce_ranges(ranges.to_vec(), self.coalesce_gap);
        {
            let mut st = self.stats.lock().unwrap();
            st.raw_ranges += ranges.len() as u64;
            st.coalesced_requests += merged.len() as u64;
            let raw: u64 = ranges.iter().map(|r| r.len).sum();
            let fetched: u64 = merged.iter().map(|r| r.len).sum();
            st.overread_bytes += fetched - raw;
        }
        // fetch merged ranges into slabs (heap when the pool is dry)
        let mut blocks: Vec<(u64, StagedBytes)> = Vec::with_capacity(merged.len());
        for m in &merged {
            // The whole request is inside the retry closure: a fresh
            // `SlabWriter` per attempt, so a fault that fires after a
            // partial `get_range_into` can never leave torn bytes in a
            // slab that a later attempt would append to.
            let block =
                fault::with_retry(self.retry, self.metrics.get(), "storage_get", || {
                    let staged = match &self.pinned {
                        Some(pool) => SlabWriter::with_capacity(pool, m.len as usize).ok(),
                        None => None,
                    };
                    Ok(match staged {
                        Some(mut w) => {
                            self.store.get_range_into(key, m.offset, m.len, &mut w)?;
                            StagedBytes::Pinned(SlabSlice::whole(w.finish()))
                        }
                        None => {
                            StagedBytes::Heap(self.store.get_range(key, m.offset, m.len)?)
                        }
                    })
                })?;
            blocks.push((m.offset, block));
        }
        // slice each requested range out of its merged block
        ranges
            .iter()
            .map(|r| {
                let (boff, block) = blocks
                    .iter()
                    .find(|(off, b)| {
                        *off <= r.offset && r.end() <= off + b.len() as u64
                    })
                    .expect("range covered by a merged block");
                let s = (r.offset - boff) as usize;
                Ok(match block {
                    StagedBytes::Pinned(slab) => {
                        StagedBytes::Pinned(slab.slice(s, r.len as usize))
                    }
                    StagedBytes::Heap(v) => {
                        StagedBytes::Heap(v[s..s + r.len as usize].to_vec())
                    }
                })
            })
            .collect()
    }
}

impl Datasource for CustomObjectStoreDatasource {
    fn footer(&self, key: &str) -> Result<Arc<FileFooter>> {
        self.flush_stale_footers();
        if let Some(f) = self.footers.lock().unwrap().get(key) {
            self.stats.lock().unwrap().footer_hits += 1;
            return Ok(f.clone());
        }
        self.stats.lock().unwrap().footer_misses += 1;
        let footer =
            fault::with_retry(self.retry, self.metrics.get(), "storage_get", || {
                let file_len = self.store.head(key)?;
                let (toff, tlen) = FileFooter::tail_range(file_len);
                let tail = self.store.get_range(key, toff, tlen)?;
                let (foff, flen) = FileFooter::footer_range(&tail, file_len)?;
                let fbytes = self.store.get_range(key, foff, flen)?;
                Ok(Arc::new(FileFooter::decode(&fbytes)?))
            })?;
        self.footers
            .lock()
            .unwrap()
            .insert(key.to_string(), footer.clone());
        Ok(footer)
    }

    fn fetch_group(
        &self,
        key: &str,
        footer: &FileFooter,
        group: usize,
        cols: &[usize],
    ) -> Result<FetchedPages> {
        let ranges = plan_ranges(&footer.row_groups[group], cols);
        self.fetch_ranges(key, &ranges)
    }

    fn name(&self) -> &'static str {
        "custom"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimContext;
    use crate::storage::compression::Codec;
    use crate::storage::format::{FileReader, FileWriter};
    use crate::storage::object_store::SimObjectStore;
    use crate::types::{Column, DType, Field, RecordBatch, Schema};

    fn test_file(rows: usize, rg: usize) -> Vec<u8> {
        let schema = Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Float32),
            Field::new("w", DType::Float64),
        ]);
        let batch = RecordBatch::new(vec![
            Column::i64("k", (0..rows as i64).collect()),
            Column::f32("v", (0..rows).map(|i| i as f32).collect()),
            Column::f64("w", (0..rows).map(|i| i as f64 * 0.5).collect()),
        ])
        .unwrap();
        let mut w = FileWriter::new(schema, Codec::Zstd { level: 1 }, rg);
        w.write(batch).unwrap();
        w.finish().unwrap()
    }

    fn store_with_file() -> (Arc<SimObjectStore>, Vec<u8>) {
        let s = SimObjectStore::in_memory(&SimContext::test());
        let f = test_file(1000, 256);
        s.put("t.ths", &f).unwrap();
        (s, f)
    }

    #[test]
    fn coalesce_merges_within_gap() {
        let rs = vec![
            ByteRange { offset: 0, len: 10 },
            ByteRange { offset: 15, len: 10 },
            ByteRange { offset: 100, len: 5 },
        ];
        let m = coalesce_ranges(rs, 8);
        assert_eq!(
            m,
            vec![
                ByteRange { offset: 0, len: 25 },
                ByteRange { offset: 100, len: 5 }
            ]
        );
        // zero gap: only adjacency merges
        let m = coalesce_ranges(
            vec![
                ByteRange { offset: 0, len: 10 },
                ByteRange { offset: 10, len: 5 },
                ByteRange { offset: 16, len: 4 },
            ],
            0,
        );
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn coalesce_handles_unsorted_and_overlapping() {
        let m = coalesce_ranges(
            vec![
                ByteRange { offset: 50, len: 10 },
                ByteRange { offset: 0, len: 60 },
            ],
            0,
        );
        assert_eq!(m, vec![ByteRange { offset: 0, len: 60 }]);
    }

    #[test]
    fn both_datasources_return_identical_pages() {
        let (s, _) = store_with_file();
        let gen = GenericDatasource::new(s.clone());
        let cust = CustomObjectStoreDatasource::new(s.clone(), 4096, None);
        let f1 = gen.footer("t.ths").unwrap();
        let f2 = cust.footer("t.ths").unwrap();
        assert_eq!(*f1, *f2);
        for g in 0..f1.row_groups.len() {
            let a = gen.fetch_group("t.ths", &f1, g, &[0, 2]).unwrap();
            let b = cust.fetch_group("t.ths", &f2, g, &[0, 2]).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn custom_issues_fewer_requests() {
        let (s, _) = store_with_file();
        let gen = GenericDatasource::new(s.clone());
        let f = gen.footer("t.ths").unwrap();
        let before = s.request_count();
        for g in 0..f.row_groups.len() {
            gen.fetch_group("t.ths", &f, g, &[0, 1, 2]).unwrap();
        }
        let gen_reqs = s.request_count() - before;

        let cust = CustomObjectStoreDatasource::new(s.clone(), 1 << 20, None);
        let before = s.request_count();
        for g in 0..f.row_groups.len() {
            cust.fetch_group("t.ths", &f, g, &[0, 1, 2]).unwrap();
        }
        let cust_reqs = s.request_count() - before;
        assert!(
            cust_reqs < gen_reqs,
            "custom {cust_reqs} should beat generic {gen_reqs}"
        );
        let st = cust.stats();
        assert!(st.coalesced_requests < st.raw_ranges);
    }

    #[test]
    fn footer_cache_hits() {
        let (s, _) = store_with_file();
        let cust = CustomObjectStoreDatasource::new(s.clone(), 0, None);
        cust.footer("t.ths").unwrap();
        let reqs = s.request_count();
        cust.footer("t.ths").unwrap();
        assert_eq!(s.request_count(), reqs, "cached footer refetched");
        let st = cust.stats();
        assert_eq!((st.footer_hits, st.footer_misses), (1, 1));
    }

    #[test]
    fn fetched_pages_decode_correctly() {
        let (s, file) = store_with_file();
        let cust = CustomObjectStoreDatasource::new(s, 1 << 20, None);
        let footer = cust.footer("t.ths").unwrap();
        let reader = FileReader::from_bytes(&file).unwrap();
        let pages = cust.fetch_group("t.ths", &footer, 0, &[0, 1]).unwrap();
        let cows: Vec<_> = pages.iter().map(|p| p.contiguous()).collect();
        let refs: Vec<&[u8]> = cows.iter().map(|c| c.as_ref()).collect();
        let batch = reader.decode_group(0, &[0, 1], &refs).unwrap();
        assert_eq!(batch.rows(), 256);
        assert_eq!(batch.column("k").unwrap().data.as_i64().unwrap()[5], 5);
    }

    #[test]
    fn pinned_bounce_buffers_exercised() {
        let (s, _) = store_with_file();
        let pool = PinnedPool::new(4096, 16).unwrap();
        let cust = CustomObjectStoreDatasource::new(s, 1 << 20, Some(pool.clone()));
        let footer = cust.footer("t.ths").unwrap();
        let pages = cust.fetch_group("t.ths", &footer, 0, &[0, 1, 2]).unwrap();
        assert!(pool.acquire_count() > 0, "bounce buffers unused");
        assert!(
            pages.iter().all(|p| p.is_pinned()),
            "pages must be slab-backed views of the coalesced fetch"
        );
        assert!(
            pool.free_buffers() < 16,
            "pages hold the slab while alive"
        );
        drop(pages);
        assert_eq!(pool.free_buffers(), 16, "bounce buffers leaked");
    }

    #[test]
    fn dry_pool_falls_back_to_heap_pages() {
        let (s, _) = store_with_file();
        let pool = PinnedPool::new(4096, 2).unwrap();
        let _hold: Vec<_> = (0..2).map(|_| pool.try_acquire().unwrap()).collect();
        let cust = CustomObjectStoreDatasource::new(s, 1 << 20, Some(pool.clone()));
        let footer = cust.footer("t.ths").unwrap();
        let pages = cust.fetch_group("t.ths", &footer, 0, &[0, 1]).unwrap();
        assert!(pages.iter().all(|p| !p.is_pinned()), "exhausted pool degrades to heap");
        assert!(!pages[0].is_empty());
    }

    #[test]
    fn source_version_bumps_monotonically_per_table() {
        let v = SourceVersion::new();
        assert_eq!(v.global(), 0);
        assert_eq!(v.of("lineitem"), 0);
        let a = v.bump("lineitem");
        let b = v.bump("orders");
        let c = v.bump("lineitem");
        assert!(a < b && b < c, "global clock strictly grows");
        assert_eq!(v.of("lineitem"), c);
        assert_eq!(v.of("orders"), b);
        assert_eq!(v.global(), c);
        let snap = v.snapshot(&["lineitem".into(), "nope".into()]);
        assert_eq!(snap, vec![("lineitem".to_string(), c), ("nope".to_string(), 0)]);
    }

    #[test]
    fn footer_cache_flushes_on_version_bump() {
        let (s, _) = store_with_file();
        let cust = CustomObjectStoreDatasource::new(s.clone(), 0, None);
        cust.footer("t.ths").unwrap();
        cust.footer("t.ths").unwrap();
        assert_eq!(cust.stats().footer_hits, 1);
        // rewrite the object: same key, one extra row group's worth
        s.put("t.ths", &test_file(2000, 256)).unwrap();
        let f = cust.footer("t.ths").unwrap();
        let st = cust.stats();
        assert_eq!(st.footer_misses, 2, "stale footer served after bump");
        assert_eq!(f.row_groups.len(), 2000usize.div_ceil(256));
    }

    #[test]
    fn overread_accounting() {
        let (s, _) = store_with_file();
        let cust = CustomObjectStoreDatasource::new(s, 1 << 20, None);
        let footer = cust.footer("t.ths").unwrap();
        // fetch non-adjacent columns 0 and 2 -> gap (col 1) is overread
        cust.fetch_group("t.ths", &footer, 0, &[0, 2]).unwrap();
        let st = cust.stats();
        assert!(st.overread_bytes > 0);
        assert_eq!(st.coalesced_requests, 1);
    }
}
