//! "THS" columnar file format — the Parquet stand-in (DESIGN.md
//! substitution #1).
//!
//! Layout (all little-endian):
//! ```text
//!   "THS1"                                  4-byte magic
//!   row group 0: col chunk 0, col chunk 1, ...   (compressed pages)
//!   row group 1: ...
//!   footer: schema, row-group metadata (per-chunk byte ranges,
//!           row counts, min/max stats), crc32
//!   footer_len: u64
//!   "THS1"                                  trailing magic
//! ```
//!
//! Deliberate Parquet parallels, because the paper's scan path depends
//! on them: the footer must be fetched *first* (Byte-Range Pre-loading
//! reads "file headers ... to identify the precise byte ranges required
//! for scan operations", §3.3.3); column chunks are independently
//! compressed ranges so projections fetch only what they need; min/max
//! stats allow row-group pruning by predicates.

use crate::storage::compression::Codec;
use crate::types::{ColumnData, RecordBatch, Schema};
use crate::util::bytes::{Reader, Writer};
use crate::{Error, Result};

pub const MAGIC: &[u8; 4] = b"THS1";

/// Byte range + stats for one column chunk within a row group.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnChunkMeta {
    /// Absolute byte offset of the compressed page in the file.
    pub offset: u64,
    /// Compressed page length.
    pub len: u64,
    /// Uncompressed payload length (device memory estimation input).
    pub uncompressed_len: u64,
    /// min/max as i64 bits (valid for i64-backed dtypes).
    pub min_i64: i64,
    pub max_i64: i64,
    /// min/max as f64 (valid for float dtypes).
    pub min_f64: f64,
    pub max_f64: f64,
}

/// Metadata for one row group.
#[derive(Clone, Debug, PartialEq)]
pub struct RowGroupMeta {
    pub rows: u64,
    /// Parallel to `schema.fields`.
    pub chunks: Vec<ColumnChunkMeta>,
}

impl RowGroupMeta {
    /// Total compressed bytes of the projected columns — the input to
    /// the exchange's size estimation and the pre-loader's range plan.
    pub fn projected_bytes(&self, cols: &[usize]) -> u64 {
        cols.iter().map(|&c| self.chunks[c].len).sum()
    }
}

/// Parsed file footer.
#[derive(Clone, Debug, PartialEq)]
pub struct FileFooter {
    pub schema: Schema,
    pub row_groups: Vec<RowGroupMeta>,
}

impl FileFooter {
    pub fn total_rows(&self) -> u64 {
        self.row_groups.iter().map(|g| g.rows).sum()
    }

    /// Can a row group be skipped for a range predicate
    /// `lo <= col < hi` on an i64-backed column? (Row-group pruning.)
    pub fn prune_i64(&self, group: usize, col: usize, lo: i64, hi: i64) -> bool {
        let c = &self.row_groups[group].chunks[col];
        c.max_i64 < lo || c.min_i64 >= hi
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.schema.encode(&mut w);
        w.u32(self.row_groups.len() as u32);
        for g in &self.row_groups {
            w.u64(g.rows);
            w.u32(g.chunks.len() as u32);
            for c in &g.chunks {
                w.u64(c.offset);
                w.u64(c.len);
                w.u64(c.uncompressed_len);
                w.i64(c.min_i64);
                w.i64(c.max_i64);
                w.f64(c.min_f64);
                w.f64(c.max_f64);
            }
        }
        let crc = crc32fast::hash(w.as_slice());
        w.u32(crc);
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<FileFooter> {
        if buf.len() < 4 {
            return Err(Error::Format("footer too short".into()));
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        if crc32fast::hash(body) != want {
            return Err(Error::Format("footer crc mismatch".into()));
        }
        let mut r = Reader::new(body);
        let schema = Schema::decode(&mut r)?;
        let ngroups = r.u32()? as usize;
        let mut row_groups = Vec::with_capacity(ngroups);
        for _ in 0..ngroups {
            let rows = r.u64()?;
            let nchunks = r.u32()? as usize;
            let mut chunks = Vec::with_capacity(nchunks);
            for _ in 0..nchunks {
                chunks.push(ColumnChunkMeta {
                    offset: r.u64()?,
                    len: r.u64()?,
                    uncompressed_len: r.u64()?,
                    min_i64: r.i64()?,
                    max_i64: r.i64()?,
                    min_f64: r.f64()?,
                    max_f64: r.f64()?,
                });
            }
            row_groups.push(RowGroupMeta { rows, chunks });
        }
        Ok(FileFooter { schema, row_groups })
    }

    /// The byte range holding `footer_len + trailing magic`, given the
    /// file size — what a reader fetches first.
    pub fn tail_range(file_len: u64) -> (u64, u64) {
        (file_len.saturating_sub(12), 12)
    }

    /// Parse the 12-byte tail into the footer's byte range.
    pub fn footer_range(tail: &[u8], file_len: u64) -> Result<(u64, u64)> {
        if tail.len() != 12 || &tail[8..12] != MAGIC {
            return Err(Error::Format("bad trailing magic".into()));
        }
        let flen = u64::from_le_bytes(tail[..8].try_into().unwrap());
        if flen + 12 > file_len {
            return Err(Error::Format("footer length exceeds file".into()));
        }
        Ok((file_len - 12 - flen, flen))
    }
}

// -------------------------------------------------------------------------
// Writer
// -------------------------------------------------------------------------

/// Streaming writer: buffers rows, flushes a row group every
/// `row_group_rows` (the paper dimensions row groups ≈128 MiB; callers
/// pick rows to match their scaled-down equivalent).
pub struct FileWriter {
    schema: Schema,
    codec: Codec,
    row_group_rows: usize,
    buf: Vec<RecordBatch>,
    buffered_rows: usize,
    out: Vec<u8>,
    groups: Vec<RowGroupMeta>,
}

impl FileWriter {
    pub fn new(schema: Schema, codec: Codec, row_group_rows: usize) -> Self {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        FileWriter {
            schema,
            codec,
            row_group_rows,
            buf: Vec::new(),
            buffered_rows: 0,
            out,
            groups: Vec::new(),
        }
    }

    pub fn write(&mut self, batch: RecordBatch) -> Result<()> {
        if batch.num_columns() != self.schema.len() {
            return Err(Error::Format(format!(
                "batch has {} columns, schema {}",
                batch.num_columns(),
                self.schema.len()
            )));
        }
        self.buffered_rows += batch.rows();
        self.buf.push(batch);
        while self.buffered_rows >= self.row_group_rows {
            self.flush_group(self.row_group_rows)?;
        }
        Ok(())
    }

    fn flush_group(&mut self, rows: usize) -> Result<()> {
        let all = RecordBatch::concat(&std::mem::take(&mut self.buf))?;
        let take = rows.min(all.rows());
        let group = all.slice(0, take)?;
        if take < all.rows() {
            self.buf.push(all.slice(take, all.rows() - take)?);
        }
        self.buffered_rows = all.rows() - take;
        if group.is_empty() {
            return Ok(());
        }

        let mut chunks = Vec::with_capacity(group.num_columns());
        for col in &group.columns {
            let raw = col.data.raw_bytes();
            let page = self.codec.compress(raw);
            let (min_i, max_i, min_f, max_f) = column_stats(&col.data);
            chunks.push(ColumnChunkMeta {
                offset: self.out.len() as u64,
                len: page.len() as u64,
                uncompressed_len: raw.len() as u64,
                min_i64: min_i,
                max_i64: max_i,
                min_f64: min_f,
                max_f64: max_f,
            });
            self.out.extend_from_slice(&page);
        }
        self.groups.push(RowGroupMeta { rows: group.rows() as u64, chunks });
        Ok(())
    }

    /// Flush remaining rows and append the footer; returns file bytes.
    pub fn finish(mut self) -> Result<Vec<u8>> {
        if self.buffered_rows > 0 {
            self.flush_group(self.buffered_rows)?;
        }
        let footer = FileFooter {
            schema: self.schema.clone(),
            row_groups: std::mem::take(&mut self.groups),
        };
        let fbytes = footer.encode();
        self.out.extend_from_slice(&fbytes);
        self.out
            .extend_from_slice(&(fbytes.len() as u64).to_le_bytes());
        self.out.extend_from_slice(MAGIC);
        Ok(self.out)
    }
}

fn column_stats(data: &ColumnData) -> (i64, i64, f64, f64) {
    match data {
        ColumnData::I64(v) => {
            let min = v.iter().copied().min().unwrap_or(i64::MAX);
            let max = v.iter().copied().max().unwrap_or(i64::MIN);
            (min, max, min as f64, max as f64)
        }
        ColumnData::F32(v) => {
            let min = v.iter().copied().fold(f32::INFINITY, f32::min);
            let max = v.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            (i64::MIN, i64::MAX, min as f64, max as f64)
        }
        ColumnData::F64(v) => {
            let min = v.iter().copied().fold(f64::INFINITY, f64::min);
            let max = v.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            (i64::MIN, i64::MAX, min, max)
        }
    }
}

// -------------------------------------------------------------------------
// Reader
// -------------------------------------------------------------------------

/// Decodes column chunks fetched by a datasource. Holds no file handle —
/// all byte movement goes through the object store, so the pre-loader
/// and the compute path share one code path (§3.3.3).
pub struct FileReader {
    pub footer: FileFooter,
}

impl FileReader {
    /// Parse a footer given the file's full bytes (local/test path).
    pub fn from_bytes(file: &[u8]) -> Result<FileReader> {
        if file.len() < 16 || &file[..4] != MAGIC {
            return Err(Error::Format("bad magic".into()));
        }
        let (tail_off, _) = FileFooter::tail_range(file.len() as u64);
        let tail = &file[tail_off as usize..];
        let (foff, flen) = FileFooter::footer_range(tail, file.len() as u64)?;
        let footer = FileFooter::decode(&file[foff as usize..(foff + flen) as usize])?;
        Ok(FileReader { footer })
    }

    /// Decode one column chunk from its fetched page bytes.
    pub fn decode_chunk(
        &self,
        group: usize,
        col: usize,
        page: &[u8],
    ) -> Result<ColumnData> {
        let meta = &self.footer.row_groups[group].chunks[col];
        if page.len() != meta.len as usize {
            return Err(Error::Format(format!(
                "chunk page length {} != meta {}",
                page.len(),
                meta.len
            )));
        }
        let raw = Codec::decompress(page)?;
        if raw.len() != meta.uncompressed_len as usize {
            return Err(Error::Format("uncompressed length mismatch".into()));
        }
        let dtype = self.footer.schema.fields[col].dtype;
        ColumnData::from_raw(ColumnData::layout_for(dtype), &raw)
    }

    /// Assemble a record batch for `group` from per-column pages.
    pub fn decode_group(
        &self,
        group: usize,
        cols: &[usize],
        pages: &[&[u8]],
    ) -> Result<RecordBatch> {
        let mut columns = Vec::with_capacity(cols.len());
        for (i, &c) in cols.iter().enumerate() {
            let field = &self.footer.schema.fields[c];
            let data = self.decode_chunk(group, c, pages[i])?;
            columns.push(crate::types::Column::new(field.name.clone(), field.dtype, data));
        }
        RecordBatch::new(columns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Column, DType, Field};
    use crate::util::rng::Rng;

    fn schema() -> Schema {
        Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Float32),
            Field::new("d", DType::Date),
        ])
    }

    fn batch(rows: usize, seed: u64) -> RecordBatch {
        let mut rng = Rng::new(seed);
        RecordBatch::new(vec![
            Column::i64("k", (0..rows).map(|_| rng.gen_i64(0, 1000)).collect()),
            Column::f32("v", (0..rows).map(|_| rng.gen_f32(0.0, 10.0)).collect()),
            Column::date("d", (0..rows).map(|i| 9000 + i as i64).collect()),
        ])
        .unwrap()
    }

    fn write_file(rows: usize, rg: usize) -> Vec<u8> {
        let mut w = FileWriter::new(schema(), Codec::Zstd { level: 1 }, rg);
        w.write(batch(rows, 1)).unwrap();
        w.finish().unwrap()
    }

    #[test]
    fn roundtrip_single_group() {
        let file = write_file(100, 1000);
        let r = FileReader::from_bytes(&file).unwrap();
        assert_eq!(r.footer.row_groups.len(), 1);
        assert_eq!(r.footer.total_rows(), 100);
        let g = &r.footer.row_groups[0];
        let pages: Vec<&[u8]> = g
            .chunks
            .iter()
            .map(|c| &file[c.offset as usize..(c.offset + c.len) as usize])
            .collect();
        let got = r.decode_group(0, &[0, 1, 2], &pages).unwrap();
        assert_eq!(got, batch(100, 1));
    }

    #[test]
    fn row_groups_split_on_boundary() {
        let file = write_file(1050, 256);
        let r = FileReader::from_bytes(&file).unwrap();
        let sizes: Vec<u64> = r.footer.row_groups.iter().map(|g| g.rows).collect();
        assert_eq!(sizes, vec![256, 256, 256, 256, 26]);
        assert_eq!(r.footer.total_rows(), 1050);
    }

    #[test]
    fn projection_reads_only_requested_columns() {
        let file = write_file(64, 64);
        let r = FileReader::from_bytes(&file).unwrap();
        let g = &r.footer.row_groups[0];
        let page = &file[g.chunks[1].offset as usize..(g.chunks[1].offset + g.chunks[1].len) as usize];
        let got = r.decode_group(0, &[1], &[page]).unwrap();
        assert_eq!(got.num_columns(), 1);
        assert_eq!(got.columns[0].name, "v");
    }

    #[test]
    fn stats_enable_pruning() {
        // dates ascend, so later groups prune against early predicates
        let file = write_file(1024, 256);
        let r = FileReader::from_bytes(&file).unwrap();
        // column 2 is d = 9000 + i; group 3 covers 9768..9024+? anyway:
        assert!(r.footer.prune_i64(3, 2, 0, 9100));
        assert!(!r.footer.prune_i64(0, 2, 0, 9100));
    }

    #[test]
    fn corrupted_footer_detected() {
        let mut file = write_file(10, 10);
        let n = file.len();
        file[n - 20] ^= 0xff; // flip a footer byte
        assert!(FileReader::from_bytes(&file).is_err());
    }

    #[test]
    fn truncated_file_detected() {
        let file = write_file(10, 10);
        assert!(FileReader::from_bytes(&file[..file.len() - 3]).is_err());
        assert!(FileReader::from_bytes(&file[..8]).is_err());
    }

    #[test]
    fn empty_write_finishes_cleanly() {
        let w = FileWriter::new(schema(), Codec::None, 16);
        let file = w.finish().unwrap();
        let r = FileReader::from_bytes(&file).unwrap();
        assert_eq!(r.footer.total_rows(), 0);
    }

    #[test]
    fn tail_and_footer_range_math() {
        let file = write_file(32, 32);
        let flen = file.len() as u64;
        let (toff, tlen) = FileFooter::tail_range(flen);
        assert_eq!(tlen, 12);
        let (foff, fl) = FileFooter::footer_range(&file[toff as usize..], flen).unwrap();
        assert!(foff + fl + 12 == flen);
    }
}
