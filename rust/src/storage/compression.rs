//! Compression codecs for data pages (file format) and network frames
//! (Network Executor, §3.3.5: "It can compress batches before sending
//! with a variety of formats").
//!
//! * `Zstd` — the paper's input format ("Parquet files compressed with
//!   Zstandard") and its network compression default.
//! * `Lz4Like` — a from-scratch byte-oriented LZ with greedy matching:
//!   much faster than zstd at a worse ratio; the knob the paper turns
//!   when CPU cycles become the bottleneck after enabling RDMA (Fig 4
//!   D→E is "free up compression cycles").
//! * `None` — passthrough.
//!
//! ## Slab-native streaming (§3.4)
//!
//! The pinned bounce pool carries bytes as fixed-size buffer chunks, so
//! every codec here works on *vectored* byte runs in both directions —
//! no codec ever forces a reassembly copy:
//!
//! * [`Codec::compress_chunks_into`] compresses `&[&[u8]]` input
//!   straight into any [`std::io::Write`] (a `SlabWriter` on the wire
//!   path). `Lz4Like` walks a [`ChunkView`] of logical offsets over the
//!   chunks, carrying its 64 KiB match window across chunk boundaries.
//! * [`Codec::decompress_slices_into`] decompresses a framed payload
//!   presented as chunks into any writer. `Lz4Like` streams through a
//!   bounded 64 KiB back-reference ring, so the full output is never
//!   materialized on the heap either.
//!
//! Length fields that arrive from the wire or disk are treated as
//! *claims*, not facts: speculative preallocation is clamped
//! ([`clamp_prealloc`]) and every decode hard-caps its output at the
//! claimed length, erroring on mismatch.

use std::io::Write;

use crate::{Error, Result};

/// Self-describing framing every compressed buffer starts with:
/// codec tag (1 byte) + original length (8 bytes LE).
pub const PRELUDE_LEN: usize = 9;

/// Available codecs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Codec {
    None,
    Zstd { level: i32 },
    Lz4Like,
}

impl Default for Codec {
    fn default() -> Self {
        Codec::Zstd { level: 1 }
    }
}

/// Clamp a speculative output preallocation derived from an untrusted
/// `orig` length claim: a corrupt or hostile frame must not make us
/// reserve gigabytes up front. 255x input is a generous ceiling on
/// realistic LZ/zstd ratios for the prealloc *hint* only — honest
/// streams beyond it just grow the buffer amortized, and the decode
/// loops still cap total output at the claim itself. (`pub(crate)`:
/// the network receive path applies the same policy to its heap
/// fallback.)
pub(crate) fn clamp_prealloc(orig: usize, input_len: usize) -> usize {
    orig.min(input_len.saturating_mul(255).saturating_add(64))
}

/// `Write` wrapper that counts bytes and (optionally) refuses to grow
/// past a limit — the output-side guard against bogus length claims.
struct CountingWriter<'a> {
    w: &'a mut dyn std::io::Write,
    written: usize,
    limit: usize,
}

impl<'a> CountingWriter<'a> {
    fn new(w: &'a mut dyn std::io::Write) -> CountingWriter<'a> {
        CountingWriter { w, written: 0, limit: usize::MAX }
    }

    fn with_limit(w: &'a mut dyn std::io::Write, limit: usize) -> CountingWriter<'a> {
        CountingWriter { w, written: 0, limit }
    }
}

impl std::io::Write for CountingWriter<'_> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.written.saturating_add(buf.len()) > self.limit {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "output exceeds claimed length",
            ));
        }
        let n = self.w.write(buf)?;
        self.written += n;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

impl Codec {
    pub fn tag(self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::Zstd { .. } => 1,
            Codec::Lz4Like => 2,
        }
    }

    pub fn from_tag(t: u8) -> Result<Codec> {
        Ok(match t {
            0 => Codec::None,
            1 => Codec::Zstd { level: 1 },
            2 => Codec::Lz4Like,
            _ => return Err(Error::Format(format!("bad codec tag {t}"))),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Zstd { .. } => "zstd",
            Codec::Lz4Like => "lz4like",
        }
    }

    /// The 9-byte self-describing framing for a payload of `orig_len`
    /// logical bytes: tag + original length.
    pub fn prelude(self, orig_len: usize) -> [u8; PRELUDE_LEN] {
        let mut p = [0u8; PRELUDE_LEN];
        p[0] = self.tag();
        p[1..9].copy_from_slice(&(orig_len as u64).to_le_bytes());
        p
    }

    /// Parse a prelude: (codec, original length). `Zstd` parses at its
    /// default level — the tag identifies the format, not the effort.
    pub fn parse_prelude(data: &[u8]) -> Result<(Codec, usize)> {
        if data.len() < PRELUDE_LEN {
            return Err(Error::Format("compressed buffer too short".into()));
        }
        let codec = Codec::from_tag(data[0])?;
        let orig = u64::from_le_bytes(data[1..9].try_into().unwrap()) as usize;
        Ok((codec, orig))
    }

    /// Compress `data`; output is self-describing (tag + original len).
    pub fn compress(self, data: &[u8]) -> Vec<u8> {
        self.compress_chunks(&[data])
    }

    /// Compress a payload presented as vectored chunks into a heap
    /// `Vec` (spill writes, file format, tests). Same streaming core as
    /// [`Codec::compress_chunks_into`] — no codec reassembles the
    /// input.
    pub fn compress_chunks(self, chunks: &[&[u8]]) -> Vec<u8> {
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        let mut out = Vec::with_capacity(total / 2 + 16);
        self.compress_chunks_into(chunks, &mut out)
            .expect("Vec write is infallible");
        out
    }

    /// Compress vectored chunks (a pinned slab's buffers) straight into
    /// `out` — the §3.4 wire path compresses into a `SlabWriter`, so a
    /// codec-enabled send stages exactly one pinned copy and never
    /// materializes an intermediate heap `Vec`. `Zstd` streams the
    /// chunks through an encoder; `Lz4Like` walks a [`ChunkView`]
    /// cursor over the chunks, matching across chunk boundaries.
    /// Returns the framed output size (prelude + body). On error (a dry
    /// pool behind a `SlabWriter`), partial output may have been
    /// written — the caller discards the writer and falls back.
    pub fn compress_chunks_into(
        self,
        chunks: &[&[u8]],
        out: &mut dyn std::io::Write,
    ) -> Result<usize> {
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        let mut cw = CountingWriter::new(out);
        cw.write_all(&self.prelude(total))?;
        match self {
            Codec::None => {
                for c in chunks {
                    cw.write_all(c)?;
                }
            }
            Codec::Zstd { level } => {
                let mut enc = zstd::stream::write::Encoder::new(&mut cw, level)
                    .map_err(|e| Error::Format(format!("zstd encoder: {e}")))?;
                for c in chunks {
                    enc.write_all(c)?;
                }
                enc.finish()?;
            }
            Codec::Lz4Like => {
                if let [one] = chunks {
                    // contiguous fast path: direct slice indexing for
                    // the ubiquitous single-slice case (spill writes,
                    // heap fallbacks, `compress`)
                    lz4like_compress_slice(one, &mut cw)?;
                } else {
                    lz4like_compress_chunks(&ChunkView::new(chunks), &mut cw)?;
                }
            }
        }
        Ok(cw.written)
    }

    /// Decompress a buffer produced by [`Codec::compress`] (any codec —
    /// the tag travels with the data, so reader config never needs to
    /// match writer config).
    pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
        let (codec, orig) = Codec::parse_prelude(data)?;
        let body = &data[PRELUDE_LEN..];
        if let Codec::Lz4Like = codec {
            // heap fast path: back-reference the output Vec directly
            // instead of going through the streaming ring
            return lz4like_decompress(body, orig);
        }
        let mut out = Vec::with_capacity(clamp_prealloc(orig, body.len()));
        let claimed = Codec::decompress_slices_into(&[data], &mut out)?;
        debug_assert_eq!(claimed, orig);
        Ok(out)
    }

    /// Decompress straight into a writer (a pinned-slab writer on the
    /// network receive and spill-promotion paths, so the decompressed
    /// bytes never stage through an intermediate heap `Vec` for *any*
    /// codec). Returns the original length, verified against the bytes
    /// actually produced.
    pub fn decompress_into(data: &[u8], out: &mut dyn std::io::Write) -> Result<usize> {
        Codec::decompress_slices_into(&[data], out)
    }

    /// Decompress a framed payload presented as vectored chunks (the
    /// prelude may span chunk boundaries) into `out`. This is the
    /// slab-to-slab receive path: compressed wire bytes in pool buffers
    /// decompress into a `SlabWriter` without reassembling input or
    /// output. `Lz4Like` streams through a bounded 64 KiB
    /// back-reference window; every codec's output is hard-capped at
    /// the claimed length and verified, so corrupt frames error instead
    /// of ballooning.
    pub fn decompress_slices_into(
        chunks: &[&[u8]],
        out: &mut dyn std::io::Write,
    ) -> Result<usize> {
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        if total < PRELUDE_LEN {
            return Err(Error::Format("compressed buffer too short".into()));
        }
        let mut cur = InCursor::new(chunks);
        let mut head = [0u8; PRELUDE_LEN];
        for slot in head.iter_mut() {
            *slot = cur.next_byte().expect("length checked above");
        }
        let (codec, orig) = Codec::parse_prelude(&head)?;
        let body_len = total - PRELUDE_LEN;
        let mut cw = CountingWriter::with_limit(out, orig);
        match codec {
            Codec::None => {
                if body_len != orig {
                    return Err(Error::Format(format!(
                        "length mismatch: body {body_len} vs claimed {orig}"
                    )));
                }
                cur.take(orig, &mut |s| cw.write_all(s).map_err(Error::from))?;
            }
            Codec::Zstd { .. } => {
                zstd::stream::copy_decode(&mut cur.reader(), &mut cw)
                    .map_err(|e| Error::Format(format!("zstd: {e}")))?;
                if cw.written != orig {
                    return Err(Error::Format(format!(
                        "zstd length mismatch: got {}, want {orig}",
                        cw.written
                    )));
                }
            }
            Codec::Lz4Like => {
                let mut sink = StreamSink::new(&mut cw);
                lz4like_decode(&mut cur, &mut sink, orig)?;
            }
        }
        Ok(orig)
    }
}

// ---------------------------------------------------------------------
// Vectored input views: ChunkView gives the compressor random access to
// logical offsets over `&[&[u8]]`; InCursor gives the decoders a
// sequential read head. Neither copies.
// ---------------------------------------------------------------------

/// Random-access view of vectored chunks as one logical byte run.
struct ChunkView<'a> {
    chunks: &'a [&'a [u8]],
    /// `starts[i]` = logical offset of `chunks[i]`; one extra trailing
    /// entry holds the total length.
    starts: Vec<usize>,
}

impl<'a> ChunkView<'a> {
    fn new(chunks: &'a [&'a [u8]]) -> ChunkView<'a> {
        let mut starts = Vec::with_capacity(chunks.len() + 1);
        let mut acc = 0usize;
        for c in chunks {
            starts.push(acc);
            acc += c.len();
        }
        starts.push(acc);
        ChunkView { chunks, starts }
    }

    fn len(&self) -> usize {
        *self.starts.last().unwrap()
    }

    /// (chunk index, offset within chunk) of logical position `pos`
    /// (`pos < len`). Empty chunks are skipped by construction: the
    /// last chunk whose start is <= pos must extend past pos.
    #[inline]
    fn locate(&self, pos: usize) -> (usize, usize) {
        debug_assert!(pos < self.len());
        let ci = self.starts.partition_point(|&s| s <= pos) - 1;
        (ci, pos - self.starts[ci])
    }

    /// Four bytes at `pos` as a little-endian word (`pos + 4 <= len`).
    #[inline]
    fn u32_at(&self, pos: usize) -> u32 {
        let (ci, off) = self.locate(pos);
        let c = self.chunks[ci];
        if off + 4 <= c.len() {
            u32::from_le_bytes(c[off..off + 4].try_into().unwrap())
        } else {
            // the word spans a chunk boundary: assemble it
            let mut b = [0u8; 4];
            for (k, slot) in b.iter_mut().enumerate() {
                let (ci, off) = self.locate(pos + k);
                *slot = self.chunks[ci][off];
            }
            u32::from_le_bytes(b)
        }
    }

    /// Length of the common prefix of the runs starting at `a` and `b`,
    /// up to `max` bytes (caller guarantees both runs stay in bounds).
    fn common_prefix(&self, a: usize, b: usize, max: usize) -> usize {
        let mut n = 0usize;
        while n < max {
            let (aci, aoff) = self.locate(a + n);
            let (bci, boff) = self.locate(b + n);
            let ac = &self.chunks[aci][aoff..];
            let bc = &self.chunks[bci][boff..];
            let step = ac.len().min(bc.len()).min(max - n);
            match ac[..step].iter().zip(&bc[..step]).position(|(x, y)| x != y) {
                Some(k) => return n + k,
                None => n += step,
            }
        }
        max
    }

    /// Write the logical range `[start, end)` chunk-wise.
    fn write_range(
        &self,
        start: usize,
        end: usize,
        out: &mut dyn std::io::Write,
    ) -> std::io::Result<()> {
        let mut pos = start;
        while pos < end {
            let (ci, off) = self.locate(pos);
            let c = self.chunks[ci];
            let n = (c.len() - off).min(end - pos);
            out.write_all(&c[off..off + n])?;
            pos += n;
        }
        Ok(())
    }
}

/// Sequential read head over vectored chunks (decoder input side).
struct InCursor<'a> {
    chunks: &'a [&'a [u8]],
    ci: usize,
    off: usize,
}

impl<'a> InCursor<'a> {
    fn new(chunks: &'a [&'a [u8]]) -> InCursor<'a> {
        InCursor { chunks, ci: 0, off: 0 }
    }

    /// Remaining bytes of the current chunk, skipping exhausted and
    /// empty chunks. Empty slice = end of input.
    #[inline]
    fn current(&mut self) -> &'a [u8] {
        while self.ci < self.chunks.len() && self.off >= self.chunks[self.ci].len() {
            self.ci += 1;
            self.off = 0;
        }
        if self.ci == self.chunks.len() {
            &[]
        } else {
            &self.chunks[self.ci][self.off..]
        }
    }

    #[inline]
    fn next_byte(&mut self) -> Option<u8> {
        let c = self.current();
        let b = *c.first()?;
        self.off += 1;
        Some(b)
    }

    /// Feed the next `len` bytes to `f` as subslices (no reassembly).
    fn take(
        &mut self,
        mut len: usize,
        f: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        while len > 0 {
            let c = self.current();
            if c.is_empty() {
                return Err(Error::Format("input truncated".into()));
            }
            let n = c.len().min(len);
            f(&c[..n])?;
            self.off += n;
            len -= n;
        }
        Ok(())
    }

    /// `Read` adapter (zstd's streaming decoder pulls from this).
    fn reader(&mut self) -> CursorRead<'_, 'a> {
        CursorRead(self)
    }
}

struct CursorRead<'c, 'a>(&'c mut InCursor<'a>);

impl std::io::Read for CursorRead<'_, '_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let c = self.0.current();
        if c.is_empty() || buf.is_empty() {
            return Ok(0);
        }
        let n = c.len().min(buf.len());
        buf[..n].copy_from_slice(&c[..n]);
        self.0.off += n;
        Ok(n)
    }
}

// ---------------------------------------------------------------------
// LZ4-like codec: greedy hash-chain LZ with 64 KiB window.
// Token stream: [literal_len: varint][match_len: varint][offset: u16]
// match_len == 0 terminates with trailing literals.
// ---------------------------------------------------------------------

const MIN_MATCH: usize = 4;
const HASH_BITS: usize = 14;
/// Match offsets are u16, so 64 KiB of history fully determines every
/// back-reference — the streaming decoder's ring size.
const LZ_WINDOW: usize = 1 << 16;

#[inline]
fn hash4(word: u32) -> usize {
    ((word.wrapping_mul(2654435761)) >> (32 - HASH_BITS)) as usize
}

fn put_varint(out: &mut dyn std::io::Write, mut v: usize) -> std::io::Result<()> {
    let mut buf = [0u8; 10];
    let mut n = 0usize;
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf[n] = b;
            n += 1;
            break;
        }
        buf[n] = b | 0x80;
        n += 1;
    }
    out.write_all(&buf[..n])
}

fn read_varint(cur: &mut InCursor) -> Result<usize> {
    let mut v = 0usize;
    let mut shift = 0;
    loop {
        let b = cur
            .next_byte()
            .ok_or_else(|| Error::Format("varint truncated".into()))?;
        v |= ((b & 0x7f) as usize) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 56 {
            return Err(Error::Format("varint overflow".into()));
        }
    }
}

/// Greedy LZ over one contiguous slice — same token stream as
/// [`lz4like_compress_chunks`] (asserted byte-identical by the property
/// suite), kept because direct indexing is markedly faster than the
/// chunk cursor and single-slice input is the common case off the hot
/// wire path.
fn lz4like_compress_slice(
    data: &[u8],
    out: &mut dyn std::io::Write,
) -> std::io::Result<()> {
    let n = data.len();
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i + MIN_MATCH <= n {
        let h = hash4(u32::from_le_bytes(data[i..i + 4].try_into().unwrap()));
        let cand = table[h];
        table[h] = i;
        if cand != usize::MAX
            && i - cand <= u16::MAX as usize
            && data[cand..cand + MIN_MATCH] == data[i..i + MIN_MATCH]
        {
            // extend the match
            let mut len = MIN_MATCH;
            while i + len < n && data[cand + len] == data[i + len] && len < 0xFFFF {
                len += 1;
            }
            put_varint(out, i - lit_start)?;
            out.write_all(&data[lit_start..i])?;
            put_varint(out, len)?;
            out.write_all(&((i - cand) as u16).to_le_bytes())?;
            i += len;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    // trailing literals with terminator (match_len 0)
    put_varint(out, n - lit_start)?;
    out.write_all(&data[lit_start..])?;
    put_varint(out, 0)
}

/// Greedy LZ over a chunked input view. Identical token output to
/// [`lz4like_compress_slice`] (the view only changes *addressing*), so
/// chunk boundaries never cost ratio: matches and literals span them
/// freely.
fn lz4like_compress_chunks(
    v: &ChunkView,
    out: &mut dyn std::io::Write,
) -> std::io::Result<()> {
    let n = v.len();
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i + MIN_MATCH <= n {
        let h = hash4(v.u32_at(i));
        let cand = table[h];
        table[h] = i;
        if cand != usize::MAX
            && i - cand <= u16::MAX as usize
            && v.common_prefix(cand, i, MIN_MATCH) == MIN_MATCH
        {
            // extend the match (capped at the window's 0xFFFF encoding)
            let cap = (n - i).min(0xFFFF);
            let len = MIN_MATCH
                + v.common_prefix(cand + MIN_MATCH, i + MIN_MATCH, cap - MIN_MATCH);
            put_varint(out, i - lit_start)?;
            v.write_range(lit_start, i, out)?;
            put_varint(out, len)?;
            out.write_all(&((i - cand) as u16).to_le_bytes())?;
            i += len;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    // trailing literals with terminator (match_len 0)
    put_varint(out, n - lit_start)?;
    v.write_range(lit_start, n, out)?;
    put_varint(out, 0)
}

/// Decoder output sink: the heap path back-references the output `Vec`
/// directly; the streaming path keeps a bounded ring window.
trait LzSink {
    fn emitted(&self) -> usize;
    fn literal(&mut self, s: &[u8]) -> Result<()>;
    /// Copy `len` bytes starting `off` back from the end of the output
    /// (`0 < off <= emitted`, validated by the decode loop); an
    /// overlapping copy repeats bytes RLE-style.
    fn copy_match(&mut self, off: usize, len: usize) -> Result<()>;
}

struct VecSink<'a>(&'a mut Vec<u8>);

impl LzSink for VecSink<'_> {
    fn emitted(&self) -> usize {
        self.0.len()
    }

    fn literal(&mut self, s: &[u8]) -> Result<()> {
        self.0.extend_from_slice(s);
        Ok(())
    }

    fn copy_match(&mut self, off: usize, len: usize) -> Result<()> {
        let start = self.0.len() - off;
        // overlapping copy (RLE case) must be byte-by-byte
        for k in 0..len {
            let b = self.0[start + k];
            self.0.push(b);
        }
        Ok(())
    }
}

/// Streams decoded bytes to any writer, keeping only the 64 KiB the
/// format can reference — the receive path decompresses into a
/// `SlabWriter` without ever holding the full output on the heap.
struct StreamSink<'a> {
    out: &'a mut dyn std::io::Write,
    ring: Box<[u8]>,
    pos: usize,
    emitted: usize,
    scratch: Vec<u8>,
}

impl<'a> StreamSink<'a> {
    fn new(out: &'a mut dyn std::io::Write) -> StreamSink<'a> {
        StreamSink {
            out,
            ring: vec![0u8; LZ_WINDOW].into_boxed_slice(),
            pos: 0,
            emitted: 0,
            scratch: Vec::new(),
        }
    }

    #[inline]
    fn push(&mut self, b: u8) {
        self.ring[self.pos] = b;
        self.pos = (self.pos + 1) & (LZ_WINDOW - 1);
    }
}

impl LzSink for StreamSink<'_> {
    fn emitted(&self) -> usize {
        self.emitted
    }

    fn literal(&mut self, s: &[u8]) -> Result<()> {
        self.out.write_all(s)?;
        for &b in s {
            self.push(b);
        }
        self.emitted += s.len();
        Ok(())
    }

    fn copy_match(&mut self, off: usize, len: usize) -> Result<()> {
        // off <= emitted and off < LZ_WINDOW (u16) guarantee the ring
        // still holds the referenced byte; pushing as we read resolves
        // overlapping (RLE) copies exactly like the Vec path.
        self.scratch.clear();
        for _ in 0..len {
            let b = self.ring[(self.pos + LZ_WINDOW - off) & (LZ_WINDOW - 1)];
            self.push(b);
            self.scratch.push(b);
            if self.scratch.len() >= 4096 {
                self.out.write_all(&self.scratch)?;
                self.scratch.clear();
            }
        }
        self.out.write_all(&self.scratch)?;
        self.emitted += len;
        Ok(())
    }
}

/// Heap decompression with the claimed-length clamp: speculative
/// preallocation never trusts `orig` beyond the input's plausible
/// expansion, and the decode loop hard-caps output at the claim.
fn lz4like_decompress(body: &[u8], orig: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(clamp_prealloc(orig, body.len()));
    let chunks = [body];
    let mut cur = InCursor::new(&chunks);
    lz4like_decode(&mut cur, &mut VecSink(&mut out), orig)?;
    Ok(out)
}

/// Token-stream decode. `orig` is the *claimed* output length, enforced
/// as a hard cap mid-stream (corrupt or hostile streams error instead
/// of producing unbounded output) and verified exactly at the end.
fn lz4like_decode(cur: &mut InCursor, sink: &mut dyn LzSink, orig: usize) -> Result<()> {
    loop {
        let lit = read_varint(cur)?;
        if sink.emitted() + lit > orig {
            return Err(Error::Format("lz4like output exceeds claimed length".into()));
        }
        cur.take(lit, &mut |s| sink.literal(s))?;
        let mlen = read_varint(cur)?;
        if mlen == 0 {
            break;
        }
        let (lo, hi) = match (cur.next_byte(), cur.next_byte()) {
            (Some(a), Some(b)) => (a, b),
            _ => return Err(Error::Format("lz4like offset truncated".into())),
        };
        let off = u16::from_le_bytes([lo, hi]) as usize;
        if off == 0 || off > sink.emitted() {
            return Err(Error::Format("lz4like bad offset".into()));
        }
        if sink.emitted() + mlen > orig {
            return Err(Error::Format("lz4like output exceeds claimed length".into()));
        }
        sink.copy_match(off, mlen)?;
    }
    if sink.emitted() != orig {
        return Err(Error::Format(format!(
            "lz4like length mismatch: got {}, want {orig}",
            sink.emitted()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn corpora() -> Vec<Vec<u8>> {
        let mut rng = Rng::new(99);
        let mut random = vec![0u8; 10_000];
        for b in random.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        let mut runs = Vec::new();
        for i in 0..50 {
            runs.extend(std::iter::repeat(i as u8).take(200));
        }
        let mut columnsish: Vec<u8> = Vec::new();
        for i in 0..2000i64 {
            columnsish.extend_from_slice(&(i / 7).to_le_bytes());
        }
        vec![
            Vec::new(),
            b"abc".to_vec(),
            b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaa".to_vec(),
            random,
            runs,
            columnsish,
        ]
    }

    #[test]
    fn roundtrip_all_codecs_all_corpora() {
        for codec in [Codec::None, Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            for data in corpora() {
                let c = codec.compress(&data);
                let d = Codec::decompress(&c).unwrap();
                assert_eq!(d, data, "codec {codec:?} corpus len {}", data.len());
            }
        }
    }

    #[test]
    fn compressible_data_shrinks() {
        let data: Vec<u8> = std::iter::repeat(b"theseus!".as_slice())
            .take(1000)
            .flatten()
            .copied()
            .collect();
        for codec in [Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            let c = codec.compress(&data);
            assert!(c.len() < data.len() / 4, "{}: {} vs {}", codec.name(), c.len(), data.len());
        }
    }

    #[test]
    fn tag_travels_with_data() {
        let data = b"cross-codec decode".to_vec();
        let c = Codec::Lz4Like.compress(&data);
        // decompress() needs no codec argument
        assert_eq!(Codec::decompress(&c).unwrap(), data);
    }

    #[test]
    fn corrupt_input_is_error_not_panic() {
        let c = Codec::Lz4Like.compress(b"hello world hello world hello");
        for cut in [0, 5, 9, c.len() - 1] {
            let _ = Codec::decompress(&c[..cut]); // must not panic
        }
        let mut bad = c.clone();
        if bad.len() > 12 {
            bad[12] ^= 0xff;
            let _ = Codec::decompress(&bad);
        }
    }

    #[test]
    fn chunked_compress_matches_whole_buffer_decode() {
        for codec in [Codec::None, Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            for data in corpora() {
                // split into uneven chunks like a slab would
                let mid = data.len() / 3;
                let mid2 = mid + (data.len() - mid) / 2;
                let chunks: Vec<&[u8]> =
                    vec![&data[..mid], &data[mid..mid2], &data[mid2..]];
                let c = codec.compress_chunks(&chunks);
                assert_eq!(
                    Codec::decompress(&c).unwrap(),
                    data,
                    "codec {codec:?} len {}",
                    data.len()
                );
            }
        }
    }

    #[test]
    fn lz4like_chunked_input_is_byte_identical_to_contiguous() {
        // The chunk-cursor view changes addressing, not the algorithm:
        // token output must match the contiguous compressor exactly,
        // for every split — including splits inside a match.
        for data in corpora() {
            let whole = Codec::Lz4Like.compress(&data);
            for nsplits in [1usize, 2, 7, 64] {
                let step = (data.len() / (nsplits + 1)).max(1);
                let mut chunks: Vec<&[u8]> = Vec::new();
                let mut pos = 0;
                while pos < data.len() {
                    let end = (pos + step).min(data.len());
                    chunks.push(&data[pos..end]);
                    pos = end;
                }
                if chunks.is_empty() {
                    chunks.push(&[]);
                }
                let split = Codec::Lz4Like.compress_chunks(&chunks);
                assert_eq!(split, whole, "len {} nsplits {nsplits}", data.len());
            }
        }
    }

    #[test]
    fn compress_chunks_into_counts_and_roundtrips() {
        let data: Vec<u8> = (0..5000u32).map(|i| (i / 3) as u8).collect();
        let chunks: Vec<&[u8]> = vec![&data[..1234], &data[1234..1235], &data[1235..]];
        for codec in [Codec::None, Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            let mut out = Vec::new();
            let n = codec.compress_chunks_into(&chunks, &mut out).unwrap();
            assert_eq!(n, out.len(), "returned size must match bytes written");
            assert_eq!(Codec::decompress(&out).unwrap(), data, "{codec:?}");
        }
    }

    #[test]
    fn decompress_into_streams_all_codecs() {
        for codec in [Codec::None, Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            for data in corpora() {
                let c = codec.compress(&data);
                let mut out = Vec::new();
                let orig = Codec::decompress_into(&c, &mut out).unwrap();
                assert_eq!(orig, data.len());
                assert_eq!(out, data, "codec {codec:?}");
            }
        }
    }

    #[test]
    fn decompress_slices_handles_split_prelude_and_body() {
        let data: Vec<u8> = std::iter::repeat(b"window".as_slice())
            .take(500)
            .flatten()
            .copied()
            .collect();
        for codec in [Codec::None, Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            let c = codec.compress(&data);
            // cut inside the prelude and at awkward body offsets
            for cuts in [[1usize, 5, 40], [8, 9, 10], [3, 200, c.len() - 1]] {
                let mut points: Vec<usize> =
                    cuts.iter().map(|&x| x.min(c.len())).collect();
                points.sort_unstable();
                let mut chunks: Vec<&[u8]> = Vec::new();
                let mut prev = 0;
                for &p in &points {
                    chunks.push(&c[prev..p]);
                    prev = p;
                }
                chunks.push(&c[prev..]);
                let mut out = Vec::new();
                let orig = Codec::decompress_slices_into(&chunks, &mut out).unwrap();
                assert_eq!(orig, data.len(), "{codec:?} cuts {cuts:?}");
                assert_eq!(out, data, "{codec:?} cuts {cuts:?}");
            }
        }
    }

    #[test]
    fn streaming_decode_handles_long_range_matches() {
        // a match whose offset is near the full 64 KiB window: the
        // streaming ring must still resolve it
        let mut rng = Rng::new(7);
        let mut data: Vec<u8> = (0..60_000).map(|_| rng.next_u64() as u8).collect();
        let head: Vec<u8> = data[..5000].to_vec();
        data.extend_from_slice(&head); // offsets ~60000 back
        let c = Codec::Lz4Like.compress(&data);
        assert!(c.len() < data.len(), "long-range matches must be found");
        let mut out = Vec::new();
        Codec::decompress_into(&c, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn hostile_length_claims_error_without_ballooning() {
        // a tiny body claiming a huge original length must fail fast:
        // prealloc is clamped and output is capped at the claim only
        // when tokens actually produce it
        let mut bogus = Codec::Lz4Like.prelude(usize::MAX / 2).to_vec();
        bogus.extend_from_slice(&[3, b'a', b'b', b'c', 0]); // 3 literals, end
        assert!(Codec::decompress(&bogus).is_err(), "length mismatch must error");

        // a match-length bomb: valid 4-byte seed then mlen far past orig
        let mut bomb = Codec::Lz4Like.prelude(10).to_vec();
        bomb.extend_from_slice(&[4, b'x', b'y', b'z', b'w']); // 4 literals
        bomb.extend_from_slice(&[0xFF, 0xFF, 0x03]); // mlen varint = 65535
        bomb.extend_from_slice(&1u16.to_le_bytes()); // offset 1
        let mut out = Vec::new();
        assert!(Codec::decompress_into(&bomb, &mut out).is_err());
        assert!(out.len() <= 10 + 4, "output must stay capped near the claim");

        // zstd: re-frame a valid stream with a lying orig
        let good = Codec::Zstd { level: 1 }.compress(&vec![7u8; 4096]);
        let mut lying = Codec::Zstd { level: 1 }.prelude(17).to_vec();
        lying.extend_from_slice(&good[PRELUDE_LEN..]);
        assert!(Codec::decompress(&lying).is_err(), "zstd output capped at claim");
    }

    #[test]
    fn prelude_roundtrip() {
        let p = Codec::Lz4Like.prelude(12345);
        let (codec, orig) = Codec::parse_prelude(&p).unwrap();
        assert_eq!((codec.tag(), orig), (2, 12345));
        assert!(Codec::parse_prelude(&p[..5]).is_err());
    }

    #[test]
    fn varint_roundtrip() {
        for v in [0usize, 1, 127, 128, 300, 1 << 20] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v).unwrap();
            let chunks: Vec<&[u8]> = vec![buf.as_slice()];
            let mut cur = InCursor::new(&chunks);
            assert_eq!(read_varint(&mut cur).unwrap(), v);
            assert!(cur.next_byte().is_none(), "varint must consume exactly");
        }
    }

    #[test]
    fn chunk_view_addressing() {
        let chunks: Vec<&[u8]> = vec![b"ab", b"", b"cdef", b"g"];
        let v = ChunkView::new(&chunks);
        assert_eq!(v.len(), 7);
        let all: Vec<u8> = (0..7)
            .map(|i| {
                let (ci, off) = v.locate(i);
                chunks[ci][off]
            })
            .collect();
        assert_eq!(all, b"abcdefg");
        assert_eq!(v.u32_at(1), u32::from_le_bytes(*b"bcde"), "cross-chunk word");
        assert_eq!(v.common_prefix(2, 2, 5), 5);
        assert_eq!(v.common_prefix(0, 2, 4), 0);
        let mut out = Vec::new();
        v.write_range(1, 6, &mut out).unwrap();
        assert_eq!(out, b"bcdef");
    }
}
