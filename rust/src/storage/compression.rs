//! Compression codecs for data pages (file format) and network frames
//! (Network Executor, §3.3.5: "It can compress batches before sending
//! with a variety of formats").
//!
//! * `Zstd` — the paper's input format ("Parquet files compressed with
//!   Zstandard") and its network compression default.
//! * `Lz4Like` — a from-scratch byte-oriented LZ with greedy matching:
//!   much faster than zstd at a worse ratio; the knob the paper turns
//!   when CPU cycles become the bottleneck after enabling RDMA (Fig 4
//!   D→E is "free up compression cycles").
//! * `None` — passthrough.

use crate::{Error, Result};

/// Self-describing framing every compressed buffer starts with:
/// codec tag (1 byte) + original length (8 bytes LE).
pub const PRELUDE_LEN: usize = 9;

/// Available codecs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Codec {
    None,
    Zstd { level: i32 },
    Lz4Like,
}

impl Default for Codec {
    fn default() -> Self {
        Codec::Zstd { level: 1 }
    }
}

impl Codec {
    pub fn tag(self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::Zstd { .. } => 1,
            Codec::Lz4Like => 2,
        }
    }

    pub fn from_tag(t: u8) -> Result<Codec> {
        Ok(match t {
            0 => Codec::None,
            1 => Codec::Zstd { level: 1 },
            2 => Codec::Lz4Like,
            _ => return Err(Error::Format(format!("bad codec tag {t}"))),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Zstd { .. } => "zstd",
            Codec::Lz4Like => "lz4like",
        }
    }

    /// The 9-byte self-describing framing for a payload of `orig_len`
    /// logical bytes: tag + original length.
    pub fn prelude(self, orig_len: usize) -> [u8; PRELUDE_LEN] {
        let mut p = [0u8; PRELUDE_LEN];
        p[0] = self.tag();
        p[1..9].copy_from_slice(&(orig_len as u64).to_le_bytes());
        p
    }

    /// Parse a prelude: (codec, original length). `Zstd` parses at its
    /// default level — the tag identifies the format, not the effort.
    pub fn parse_prelude(data: &[u8]) -> Result<(Codec, usize)> {
        if data.len() < PRELUDE_LEN {
            return Err(Error::Format("compressed buffer too short".into()));
        }
        let codec = Codec::from_tag(data[0])?;
        let orig = u64::from_le_bytes(data[1..9].try_into().unwrap()) as usize;
        Ok((codec, orig))
    }

    /// Compress `data`; output is self-describing (tag + original len).
    pub fn compress(self, data: &[u8]) -> Vec<u8> {
        self.compress_chunks(&[data])
    }

    /// Compress a payload presented as vectored chunks (a pinned slab's
    /// buffers) without first reassembling it. `Zstd` streams the
    /// chunks through an encoder; `Lz4Like` needs random access to its
    /// input window, so it alone materializes the input first.
    pub fn compress_chunks(self, chunks: &[&[u8]]) -> Vec<u8> {
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        let mut out = Vec::with_capacity(total / 2 + 16);
        out.extend_from_slice(&self.prelude(total));
        match self {
            Codec::None => {
                for c in chunks {
                    out.extend_from_slice(c);
                }
            }
            Codec::Zstd { level } => {
                use std::io::Write;
                let mut enc =
                    zstd::stream::write::Encoder::new(out, level).expect("zstd encoder");
                for c in chunks {
                    enc.write_all(c).expect("zstd compress");
                }
                out = enc.finish().expect("zstd finish");
            }
            Codec::Lz4Like => {
                if let [one] = chunks {
                    lz4like_compress(one, &mut out);
                } else {
                    let mut all = Vec::with_capacity(total);
                    for c in chunks {
                        all.extend_from_slice(c);
                    }
                    lz4like_compress(&all, &mut out);
                }
            }
        }
        out
    }

    /// Decompress a buffer produced by [`Codec::compress`] (any codec —
    /// the tag travels with the data, so reader config never needs to
    /// match writer config).
    pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
        let (codec, orig) = Codec::parse_prelude(data)?;
        let body = &data[PRELUDE_LEN..];
        match codec {
            Codec::None => Ok(body.to_vec()),
            Codec::Zstd { .. } => zstd::bulk::decompress(body, orig)
                .map_err(|e| Error::Format(format!("zstd: {e}"))),
            Codec::Lz4Like => lz4like_decompress(body, orig),
        }
    }

    /// Decompress straight into a writer (a pinned-slab writer on the
    /// spill-promotion path, so the decompressed bytes never stage
    /// through an intermediate heap `Vec` for `Zstd`/`None`). Returns
    /// the claimed original length; the caller should verify the writer
    /// grew by exactly that much.
    pub fn decompress_into(data: &[u8], out: &mut dyn std::io::Write) -> Result<usize> {
        use std::io::Write;
        let (codec, orig) = Codec::parse_prelude(data)?;
        let body = &data[PRELUDE_LEN..];
        match codec {
            Codec::None => {
                if body.len() != orig {
                    return Err(Error::Format(format!(
                        "length mismatch: body {} vs claimed {orig}",
                        body.len()
                    )));
                }
                out.write_all(body)?;
            }
            Codec::Zstd { .. } => {
                zstd::stream::copy_decode(body, &mut *out)
                    .map_err(|e| Error::Format(format!("zstd: {e}")))?;
            }
            Codec::Lz4Like => {
                let v = lz4like_decompress(body, orig)?;
                out.write_all(&v)?;
            }
        }
        Ok(orig)
    }
}

// ---------------------------------------------------------------------
// LZ4-like codec: greedy hash-chain LZ with 64 KiB window.
// Token stream: [literal_len: varint][match_len: varint][offset: u16]
// match_len == 0 terminates with trailing literals.
// ---------------------------------------------------------------------

const MIN_MATCH: usize = 4;
const HASH_BITS: usize = 14;

#[inline]
fn hash4(b: &[u8]) -> usize {
    let v = u32::from_le_bytes(b[..4].try_into().unwrap());
    ((v.wrapping_mul(2654435761)) >> (32 - HASH_BITS)) as usize
}

fn put_varint(out: &mut Vec<u8>, mut v: usize) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

fn get_varint(data: &[u8], pos: &mut usize) -> Result<usize> {
    let mut v = 0usize;
    let mut shift = 0;
    loop {
        let b = *data
            .get(*pos)
            .ok_or_else(|| Error::Format("varint truncated".into()))?;
        *pos += 1;
        v |= ((b & 0x7f) as usize) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 56 {
            return Err(Error::Format("varint overflow".into()));
        }
    }
}

fn lz4like_compress(data: &[u8], out: &mut Vec<u8>) {
    let n = data.len();
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i + MIN_MATCH <= n {
        let h = hash4(&data[i..]);
        let cand = table[h];
        table[h] = i;
        if cand != usize::MAX
            && i - cand <= u16::MAX as usize
            && data[cand..cand + MIN_MATCH] == data[i..i + MIN_MATCH]
        {
            // extend the match
            let mut len = MIN_MATCH;
            while i + len < n && data[cand + len] == data[i + len] && len < 0xFFFF {
                len += 1;
            }
            put_varint(out, i - lit_start);
            out.extend_from_slice(&data[lit_start..i]);
            put_varint(out, len);
            out.extend_from_slice(&((i - cand) as u16).to_le_bytes());
            i += len;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    // trailing literals with terminator (match_len 0)
    put_varint(out, n - lit_start);
    out.extend_from_slice(&data[lit_start..]);
    put_varint(out, 0);
}

fn lz4like_decompress(data: &[u8], orig: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(orig);
    let mut pos = 0usize;
    loop {
        let lit = get_varint(data, &mut pos)?;
        if pos + lit > data.len() {
            return Err(Error::Format("lz4like literal overrun".into()));
        }
        out.extend_from_slice(&data[pos..pos + lit]);
        pos += lit;
        let mlen = get_varint(data, &mut pos)?;
        if mlen == 0 {
            break;
        }
        if pos + 2 > data.len() {
            return Err(Error::Format("lz4like offset truncated".into()));
        }
        let off = u16::from_le_bytes(data[pos..pos + 2].try_into().unwrap()) as usize;
        pos += 2;
        if off == 0 || off > out.len() {
            return Err(Error::Format("lz4like bad offset".into()));
        }
        let start = out.len() - off;
        // overlapping copy (RLE case) must be byte-by-byte
        for k in 0..mlen {
            let b = out[start + k];
            out.push(b);
        }
    }
    if out.len() != orig {
        return Err(Error::Format(format!(
            "lz4like length mismatch: got {}, want {orig}",
            out.len()
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn corpora() -> Vec<Vec<u8>> {
        let mut rng = Rng::new(99);
        let mut random = vec![0u8; 10_000];
        for b in random.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        let mut runs = Vec::new();
        for i in 0..50 {
            runs.extend(std::iter::repeat(i as u8).take(200));
        }
        let mut columnsish: Vec<u8> = Vec::new();
        for i in 0..2000i64 {
            columnsish.extend_from_slice(&(i / 7).to_le_bytes());
        }
        vec![
            Vec::new(),
            b"abc".to_vec(),
            b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaa".to_vec(),
            random,
            runs,
            columnsish,
        ]
    }

    #[test]
    fn roundtrip_all_codecs_all_corpora() {
        for codec in [Codec::None, Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            for data in corpora() {
                let c = codec.compress(&data);
                let d = Codec::decompress(&c).unwrap();
                assert_eq!(d, data, "codec {codec:?} corpus len {}", data.len());
            }
        }
    }

    #[test]
    fn compressible_data_shrinks() {
        let data: Vec<u8> = std::iter::repeat(b"theseus!".as_slice())
            .take(1000)
            .flatten()
            .copied()
            .collect();
        for codec in [Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            let c = codec.compress(&data);
            assert!(c.len() < data.len() / 4, "{}: {} vs {}", codec.name(), c.len(), data.len());
        }
    }

    #[test]
    fn tag_travels_with_data() {
        let data = b"cross-codec decode".to_vec();
        let c = Codec::Lz4Like.compress(&data);
        // decompress() needs no codec argument
        assert_eq!(Codec::decompress(&c).unwrap(), data);
    }

    #[test]
    fn corrupt_input_is_error_not_panic() {
        let c = Codec::Lz4Like.compress(b"hello world hello world hello");
        for cut in [0, 5, 9, c.len() - 1] {
            let _ = Codec::decompress(&c[..cut]); // must not panic
        }
        let mut bad = c.clone();
        if bad.len() > 12 {
            bad[12] ^= 0xff;
            let _ = Codec::decompress(&bad);
        }
    }

    #[test]
    fn chunked_compress_matches_whole_buffer_decode() {
        for codec in [Codec::None, Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            for data in corpora() {
                // split into uneven chunks like a slab would
                let mid = data.len() / 3;
                let mid2 = mid + (data.len() - mid) / 2;
                let chunks: Vec<&[u8]> =
                    vec![&data[..mid], &data[mid..mid2], &data[mid2..]];
                let c = codec.compress_chunks(&chunks);
                assert_eq!(
                    Codec::decompress(&c).unwrap(),
                    data,
                    "codec {codec:?} len {}",
                    data.len()
                );
            }
        }
    }

    #[test]
    fn decompress_into_streams_all_codecs() {
        for codec in [Codec::None, Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            for data in corpora() {
                let c = codec.compress(&data);
                let mut out = Vec::new();
                let orig = Codec::decompress_into(&c, &mut out).unwrap();
                assert_eq!(orig, data.len());
                assert_eq!(out, data, "codec {codec:?}");
            }
        }
    }

    #[test]
    fn prelude_roundtrip() {
        let p = Codec::Lz4Like.prelude(12345);
        let (codec, orig) = Codec::parse_prelude(&p).unwrap();
        assert_eq!((codec.tag(), orig), (2, 12345));
        assert!(Codec::parse_prelude(&p[..5]).is_err());
    }

    #[test]
    fn varint_roundtrip() {
        for v in [0usize, 1, 127, 128, 300, 1 << 20] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }
}
