//! Storage layer: columnar file format, object-store simulator, and the
//! two datasource implementations the paper ablates (Fig 4 F→G).

pub mod compression;
pub mod datasource;
pub mod format;
pub mod object_store;

pub use compression::Codec;
pub use datasource::{
    CustomObjectStoreDatasource, Datasource, GenericDatasource, SourceVersion,
};
pub use format::{ColumnChunkMeta, FileFooter, FileReader, FileWriter, RowGroupMeta};
pub use object_store::{ObjectStore, SimObjectStore};
