//! Object-store simulator: byte-range GETs over local files, shaped by
//! the per-connection latency/bandwidth of the modeled store (S3 in the
//! cloud profile, WEKA on-prem) and a bounded hot-connection pool.
//!
//! This is the substrate under both datasources (§3.3.4) and the
//! Byte-Range Pre-loader (§3.3.3). Theseus "does not ingest the data it
//! is operating on, but rather reads data directly from raw files" — so
//! every byte a query touches flows through [`ObjectStore::get_range`].

use std::collections::HashMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use crate::sim::{SimContext, Throttle};
use crate::storage::datasource::SourceVersion;
use crate::{Error, Result};

/// Byte-range read interface (the only way to touch stored bytes).
pub trait ObjectStore: Send + Sync {
    /// Total object size, if it exists.
    fn head(&self, key: &str) -> Result<u64>;

    /// Read `len` bytes at `offset`. One modeled store request.
    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>>;

    /// Read `len` bytes at `offset` straight into `out` (a pinned
    /// [`crate::memory::SlabWriter`] on the pre-load staging path, so
    /// fetched bytes land in bounce buffers without an intermediate
    /// heap `Vec`). One modeled store request. The default shims via
    /// [`ObjectStore::get_range`] for implementations that predate it.
    fn get_range_into(
        &self,
        key: &str,
        offset: u64,
        len: u64,
        out: &mut dyn std::io::Write,
    ) -> Result<()> {
        let v = self.get_range(key, offset, len)?;
        out.write_all(&v)?;
        Ok(())
    }

    /// Store an object (datagen / shuffle-to-storage path).
    fn put(&self, key: &str, data: &[u8]) -> Result<()>;

    /// List keys with a prefix, sorted.
    fn list(&self, prefix: &str) -> Result<Vec<String>>;

    /// Lifetime GET-request count (the coalescing win in Fig-4 G shows
    /// up here).
    fn request_count(&self) -> u64;

    /// Lifetime bytes served.
    fn bytes_served(&self) -> u64;

    /// The store's mutation clock, when it tracks one. Writes through
    /// [`ObjectStore::put`] bump the table the key belongs to (the
    /// prefix before the first `/`); caches derived from stored bytes
    /// validate against these stamps. Default: no tracking.
    fn source_version(&self) -> Option<SourceVersion> {
        None
    }
}

/// Simulated store: objects on the local filesystem (or in memory),
/// each request paying the profile's storage latency and drawing from a
/// bounded pool of per-connection bandwidth throttles.
pub struct SimObjectStore {
    root: Option<PathBuf>,
    /// In-memory objects (tests and small workloads avoid disk churn).
    mem: RwLock<HashMap<String, Arc<Vec<u8>>>>,
    /// One throttle per modeled connection; a request must hold a
    /// connection slot for its duration.
    conns: Vec<Throttle>,
    slot: Mutex<Vec<usize>>,
    slot_free: Condvar,
    requests: AtomicU64,
    bytes: AtomicU64,
    waits: AtomicU64,
    version: SourceVersion,
}

impl SimObjectStore {
    /// Purely in-memory store shaped by `ctx`'s storage link.
    pub fn in_memory(ctx: &SimContext) -> Arc<Self> {
        Self::build(None, ctx)
    }

    /// Store rooted at a directory; objects are files under it.
    pub fn at_dir(root: impl Into<PathBuf>, ctx: &SimContext) -> Arc<Self> {
        Self::build(Some(root.into()), ctx)
    }

    fn build(root: Option<PathBuf>, ctx: &SimContext) -> Arc<Self> {
        let n = ctx.profile.storage_conns.max(1);
        SimObjectStore {
            root,
            mem: RwLock::new(HashMap::new()),
            conns: (0..n).map(|_| ctx.throttle(&ctx.profile.storage)).collect(),
            slot: Mutex::new((0..n).collect()),
            slot_free: Condvar::new(),
            requests: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            waits: AtomicU64::new(0),
            version: SourceVersion::new(),
        }
        .into()
    }

    /// Times a request had to wait for a free connection (saturation
    /// signal; the custom datasource's pooling keeps this low).
    pub fn connection_waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    pub fn num_connections(&self) -> usize {
        self.conns.len()
    }

    fn with_conn<T>(&self, nbytes: usize, f: impl FnOnce() -> Result<T>) -> Result<T> {
        // take a connection slot (bounded concurrency)
        let idx = {
            let mut free = self.slot.lock().unwrap();
            if free.is_empty() {
                self.waits.fetch_add(1, Ordering::Relaxed);
            }
            loop {
                if let Some(i) = free.pop() {
                    break i;
                }
                free = self.slot_free.wait(free).unwrap();
            }
        };
        // pay latency + bandwidth on that connection
        self.conns[idx].acquire(nbytes);
        let out = f();
        let mut free = self.slot.lock().unwrap();
        free.push(idx);
        // Notify while the lock is held (lost-wakeup defense — see
        // CONCURRENCY.md on wait/notify pairings).
        self.slot_free.notify_one();
        out
    }

    fn path_of(&self, key: &str) -> Option<PathBuf> {
        self.root.as_ref().map(|r| r.join(key))
    }
}

impl ObjectStore for SimObjectStore {
    fn head(&self, key: &str) -> Result<u64> {
        if let Some(data) = self.mem.read().unwrap().get(key) {
            return Ok(data.len() as u64);
        }
        if let Some(p) = self.path_of(key) {
            if let Ok(md) = std::fs::metadata(&p) {
                return Ok(md.len());
            }
        }
        Err(Error::ObjectStore(format!("no such object: {key}")))
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        crate::fault::check(crate::fault::FaultSite::StorageGet)?;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(len, Ordering::Relaxed);
        self.with_conn(len as usize, || {
            if let Some(data) = self.mem.read().unwrap().get(key).cloned() {
                let end = offset + len;
                if end > data.len() as u64 {
                    return Err(Error::ObjectStore(format!(
                        "range {offset}+{len} beyond object {key} ({} bytes)",
                        data.len()
                    )));
                }
                return Ok(data[offset as usize..end as usize].to_vec());
            }
            let p = self
                .path_of(key)
                .ok_or_else(|| Error::ObjectStore(format!("no such object: {key}")))?;
            let mut f = File::open(&p)
                .map_err(|e| Error::ObjectStore(format!("{key}: {e}")))?;
            f.seek(SeekFrom::Start(offset))?;
            let mut buf = vec![0u8; len as usize];
            f.read_exact(&mut buf)
                .map_err(|e| Error::ObjectStore(format!("{key} range: {e}")))?;
            Ok(buf)
        })
    }

    fn get_range_into(
        &self,
        key: &str,
        offset: u64,
        len: u64,
        out: &mut dyn std::io::Write,
    ) -> Result<()> {
        crate::fault::check(crate::fault::FaultSite::StorageGet)?;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(len, Ordering::Relaxed);
        self.with_conn(len as usize, || {
            // `.cloned()` bumps the object's Arc refcount (releasing the
            // map lock early); it does not copy the data.
            if let Some(data) = self.mem.read().unwrap().get(key).cloned() {
                let end = offset + len;
                if end > data.len() as u64 {
                    return Err(Error::ObjectStore(format!(
                        "range {offset}+{len} beyond object {key} ({} bytes)",
                        data.len()
                    )));
                }
                // straight from the stored object into the caller's
                // buffers — no intermediate Vec
                out.write_all(&data[offset as usize..end as usize])?;
                return Ok(());
            }
            let p = self
                .path_of(key)
                .ok_or_else(|| Error::ObjectStore(format!("no such object: {key}")))?;
            let mut f = File::open(&p)
                .map_err(|e| Error::ObjectStore(format!("{key}: {e}")))?;
            f.seek(SeekFrom::Start(offset))?;
            let copied = std::io::copy(&mut f.by_ref().take(len), out)?;
            if copied != len {
                return Err(Error::ObjectStore(format!(
                    "{key} range: short read ({copied} of {len} bytes)"
                )));
            }
            Ok(())
        })
    }

    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        crate::fault::check(crate::fault::FaultSite::StoragePut)?;
        if let Some(p) = self.path_of(key) {
            if let Some(dir) = p.parent() {
                std::fs::create_dir_all(dir)?;
            }
            std::fs::write(&p, data)?;
        } else {
            self.mem
                .write()
                .unwrap()
                .insert(key.to_string(), Arc::new(data.to_vec()));
        }
        // bytes are in place — now advertise the change (readers that
        // validate after this see the new stamp and refetch)
        let table = key.split('/').next().unwrap_or(key);
        self.version.bump(table);
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let mut keys: Vec<String> = self
            .mem
            .read()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        if let Some(root) = &self.root {
            fn walk(dir: &std::path::Path, root: &std::path::Path, out: &mut Vec<String>) {
                if let Ok(rd) = std::fs::read_dir(dir) {
                    for e in rd.flatten() {
                        let p = e.path();
                        if p.is_dir() {
                            walk(&p, root, out);
                        } else if let Ok(rel) = p.strip_prefix(root) {
                            out.push(rel.to_string_lossy().into_owned());
                        }
                    }
                }
            }
            let mut fs_keys = Vec::new();
            walk(root, root, &mut fs_keys);
            keys.extend(fs_keys.into_iter().filter(|k| k.starts_with(prefix)));
        }
        keys.sort();
        keys.dedup();
        Ok(keys)
    }

    fn request_count(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    fn bytes_served(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn source_version(&self) -> Option<SourceVersion> {
        Some(self.version.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> Arc<SimObjectStore> {
        SimObjectStore::in_memory(&SimContext::test())
    }

    #[test]
    fn put_head_get_roundtrip() {
        let s = store();
        s.put("a/b.ths", &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(s.head("a/b.ths").unwrap(), 5);
        assert_eq!(s.get_range("a/b.ths", 1, 3).unwrap(), vec![2, 3, 4]);
        assert_eq!(s.request_count(), 1);
        assert_eq!(s.bytes_served(), 3);
    }

    #[test]
    fn get_range_into_writes_directly() {
        let s = store();
        s.put("a", &[10, 20, 30, 40, 50]).unwrap();
        let mut out = Vec::new();
        s.get_range_into("a", 1, 3, &mut out).unwrap();
        assert_eq!(out, vec![20, 30, 40]);
        assert_eq!(s.request_count(), 1);
        assert!(s.get_range_into("a", 4, 9, &mut out).is_err());
    }

    #[test]
    fn missing_object_is_error() {
        let s = store();
        assert!(s.head("nope").is_err());
        assert!(s.get_range("nope", 0, 1).is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let s = store();
        s.put("x", &[0; 10]).unwrap();
        assert!(s.get_range("x", 8, 5).is_err());
    }

    #[test]
    fn list_filters_and_sorts() {
        let s = store();
        s.put("t/lineitem/0.ths", b"a").unwrap();
        s.put("t/orders/0.ths", b"b").unwrap();
        s.put("t/lineitem/1.ths", b"c").unwrap();
        assert_eq!(
            s.list("t/lineitem/").unwrap(),
            vec!["t/lineitem/0.ths", "t/lineitem/1.ths"]
        );
        assert_eq!(s.list("").unwrap().len(), 3);
    }

    #[test]
    fn dir_backed_store_reads_files() {
        let dir = std::env::temp_dir().join(format!("theseus-os-{}", std::process::id()));
        let s = SimObjectStore::at_dir(&dir, &SimContext::test());
        s.put("tbl/part-0.ths", b"hello world").unwrap();
        assert_eq!(s.head("tbl/part-0.ths").unwrap(), 11);
        assert_eq!(s.get_range("tbl/part-0.ths", 6, 5).unwrap(), b"world");
        assert_eq!(s.list("tbl/").unwrap(), vec!["tbl/part-0.ths"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_gets_share_bounded_connections() {
        let s = store();
        s.put("k", &vec![7u8; 4096]).unwrap();
        let hs: Vec<_> = (0..16)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || s.get_range("k", 0, 4096).unwrap().len())
            })
            .collect();
        for h in hs {
            assert_eq!(h.join().unwrap(), 4096);
        }
        assert_eq!(s.request_count(), 16);
    }
}
