//! Configuration: every knob §4.1 turns, plus the named presets A–I
//! that reproduce Figure 4.
//!
//! The file format is a TOML subset (`key = value` lines with optional
//! `[section]` headers, `#` comments, strings, ints, floats, bools)
//! parsed by [`toml_lite`] — no external dependency, explicit grammar.
//!
//! ## Exchange flow-control knobs
//!
//! The shuffle's movement-control feedback loop (§3.3) is tuned by four
//! related knobs, validated together:
//!
//! | knob                           | default | constraint                  |
//! |--------------------------------|---------|-----------------------------|
//! | `exchange_flush_bytes`         | 4 MiB   | `1 ..= max_frame_bytes/2`   |
//! | `exchange_flush_floor_bytes`   | 64 KiB  | `1 ..= ceiling`             |
//! | `exchange_flush_ceiling_bytes` | 4 MiB   | `floor ..= max_frame_bytes/2` |
//! | `exchange_initial_credits`    | 32      | `>= 1`                      |
//!
//! `exchange_flush_bytes` is the *starting* per-destination flush
//! threshold; the adaptive controller then moves each destination's
//! threshold inside `[floor, ceiling]` from observed outbox depth and
//! send latency. Pinning `floor == ceiling` turns adaptation off.
//! `exchange_initial_credits` is the per-destination startup window of
//! data frames a sender may have in flight before the receiver's first
//! credit grant arrives — the common (keeping-up) case never stalls.
//!
//! ## Serving-layer cache knobs
//!
//! The gateway's two-level cache (see [`crate::cache`]) is sized by two
//! byte budgets, both defaulting to **0 = off** so nothing changes for
//! existing deployments unless opted in:
//!
//! | knob                   | default | meaning                                  |
//! |------------------------|---------|------------------------------------------|
//! | `result_cache_bytes`   | 0 (off) | exact-result LRU budget at the gateway   |
//! | `fragment_cache_bytes` | 0 (off) | materialized scan→filter→agg fragments   |
//!
//! Nonzero budgets must be at least 1 KiB (anything smaller could never
//! admit an entry). Cache bytes are accounted against a gateway-side
//! memory governor; refused grows evict LRU entries rather than wedge.
//!
//! ## Gateway session knobs
//!
//! The concurrent-submission session layer (see [`crate::cluster`]) is
//! tuned by three knobs:
//!
//! | knob                       | default  | constraint |
//! |----------------------------|----------|------------|
//! | `query_timeout_ms`         | 300000   | `>= 1`     |
//! | `admission_capacity_bytes` | 0        | none (0 = device_capacity) |
//! | `admission_bypass_limit`   | 4        | `>= 1`     |
//!
//! `query_timeout_ms` is the per-query execution deadline; sessions can
//! override it per submission. `admission_capacity_bytes` caps the
//! aggregate scan footprint of concurrently *admitted* queries (0 uses
//! the worker device capacity — admission then mirrors governor
//! headroom). `admission_bypass_limit` is the starvation bound: a
//! queued query may be overtaken by at most this many later, higher-
//! priority arrivals before it becomes the forced head of the queue.
//!
//! ## Fault-recovery knobs
//!
//! Transient-fault recovery (see FAULTS.md at the repo root) is tuned
//! by three knobs:
//!
//! | knob                      | default | meaning                                  |
//! |---------------------------|---------|------------------------------------------|
//! | `storage_retry_limit`     | 3       | max attempts per object-store read       |
//! | `storage_backoff_base_ms` | 10      | base of the exponential retry backoff    |
//! | `query_retry_limit`       | 2       | gateway re-runs after a transient failure |
//!
//! `storage_retry_limit` counts *attempts* (a value below 1 behaves as
//! 1 — the read always runs once); `storage_backoff_base_ms = 0` means
//! retry immediately. `query_retry_limit` counts *re-runs* after the
//! first attempt; `0` turns query-level retry off. All three are
//! unconstrained — every value has a defined meaning — so they appear
//! in `lockorder.toml`'s `allow_unvalidated` list.

pub mod toml_lite;

pub use toml_lite::TomlLite;

use crate::sim::HwProfile;
use crate::storage::compression::Codec;
use crate::{Error, Result};

/// Which network back-end the Network Executor uses (§3.3.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// POSIX TCP (IPoIB on the on-prem fabric). Fig-4 configs A–C.
    Tcp,
    /// UCX/GPUDirect-RDMA-like: higher bandwidth, lower per-message
    /// cost. Fig-4 configs D–E.
    Rdma,
    /// In-process channels shaped like Tcp (single-process clusters).
    Inproc,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "tcp" => TransportKind::Tcp,
            "rdma" => TransportKind::Rdma,
            "inproc" => TransportKind::Inproc,
            _ => return Err(Error::Config(format!("unknown transport '{s}'"))),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Tcp => "tcp",
            TransportKind::Rdma => "rdma",
            TransportKind::Inproc => "inproc",
        }
    }
}

/// Which datasource implementation scans use (§3.3.4, Fig-4 F→G).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasourceKind {
    Generic,
    Custom,
}

impl DatasourceKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "generic" => DatasourceKind::Generic,
            "custom" => DatasourceKind::Custom,
            _ => return Err(Error::Config(format!("unknown datasource '{s}'"))),
        })
    }
}

/// Full worker configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    // ---- topology
    /// Workers in the cluster (every worker knows the fanout).
    pub num_workers: usize,
    /// Compute executor threads ("All executors have a number of
    /// configurable CPU threads", §3.3).
    pub compute_threads: usize,
    /// Data-Movement executor threads (demotion/promotion plans).
    pub memory_threads: usize,
    /// Pre-load executor threads (§3.3.3 byte-range / task preload).
    pub preload_threads: usize,
    /// Network executor threads (send/recv pumps per transport).
    pub network_threads: usize,

    // ---- memory
    /// Device (simulated GPU) memory per worker, bytes.
    pub device_capacity: usize,
    /// Pinned pool enabled (§3.4; Fig-4 C).
    pub pinned_pool: bool,
    /// Pinned pool: bytes per fixed-size buffer.
    pub pinned_buf_size: usize,
    /// Pinned pool: number of buffers (pool capacity = size × count).
    pub pinned_buffers: usize,
    /// Data-Movement spill watermark (fraction of device capacity):
    /// allocations crossing it raise device pressure.
    pub spill_watermark: f64,
    /// Data-Movement promotion gate: promotions pause while device
    /// utilization exceeds this fraction (promotion must not fight
    /// demotion).
    pub promote_watermark: f64,
    /// Urgency of demotions answering failed allocations / blocked
    /// reservations (higher runs earlier in the movement queue).
    pub urgency_reservation: i64,
    /// Urgency of proactive watermark demotions.
    pub urgency_watermark: i64,
    /// Residency-aware compute scheduling (§3.3.1 "the memory tier that
    /// the input data resides in"): bonus added to a queued task's
    /// priority scaled by its inputs' device-resident byte fraction.
    /// Both bonus knobs zero (the default) turn the feature off — task
    /// ordering is then exactly `priority + FIFO`.
    pub residency_bonus_device: i64,
    /// Penalty subtracted scaled by the inputs' spilled byte fraction.
    /// The penalty decays by half per re-rank pass, so delayed tasks
    /// are never starved.
    pub residency_penalty_spilled: i64,
    /// Max queued tasks re-scored per residency re-rank pass.
    pub residency_rerank_batch: usize,
    /// Codec for host→disk spills.
    pub spill_codec: Codec,
    /// Spill-file rotation size, bytes (dead sealed segments are
    /// reclaimed eagerly).
    pub spill_segment_bytes: u64,
    /// Reservation wait deadline, ms.
    pub reservation_timeout_ms: u64,

    // ---- batching
    /// Rows per device batch (padded to the AOT shape).
    pub batch_rows: usize,
    /// Adaptive Exchange: broadcast instead of hash-partition when the
    /// estimated total bytes are below this (§3.2).
    pub broadcast_threshold: usize,
    /// Adaptive Exchange: batches to accumulate before estimating.
    pub exchange_estimate_batches: usize,
    /// Coalescing shuffle (§3.4): a per-destination exchange buffer
    /// flushes to the wire once it holds this many bytes (plus early
    /// under memory pressure and on upstream finish). The default
    /// (~4 MiB) targets slab-friendly frames — many pool buffers per
    /// message instead of many messages per pool buffer. `1` disables
    /// coalescing (every routed batch flushes immediately, the seed's
    /// per-fragment behavior). Validated to at most
    /// `max_frame_bytes / 2` so a flush that overshoots the threshold
    /// still clears the receiver's frame-length guard.
    pub exchange_flush_bytes: usize,
    /// Adaptive flush controller floor (bytes): a congested destination
    /// (deep outbox, rising send latency) has its flush threshold
    /// halved per adaptation step, but never below this — frames keep a
    /// minimum useful size even on a struggling path. Default 64 KiB.
    pub exchange_flush_floor_bytes: usize,
    /// Adaptive flush controller ceiling (bytes): an uncongested
    /// destination grows its threshold toward this, coalescing bigger
    /// frames. Validated to at most `max_frame_bytes / 2` (same
    /// overshoot headroom as `exchange_flush_bytes`). Set equal to the
    /// floor to pin the threshold and disable adaptation. Default
    /// 4 MiB.
    pub exchange_flush_ceiling_bytes: usize,
    /// Credit-based exchange backpressure: data frames a sender may
    /// have outstanding per destination before the receiver's first
    /// credit grant. Receivers return one credit per drained batch, so
    /// a consumer that keeps up never stalls its senders while a slow
    /// one bounds them to this window. Must be >= 1 (a zero window
    /// could never send the first frame). Default 32.
    pub exchange_initial_credits: usize,

    // ---- serving-layer caches (gateway-side, see `crate::cache`)
    /// Exact-result cache budget, bytes. `0` (the default) disables the
    /// result cache entirely — `Gateway::submit` always executes.
    pub result_cache_bytes: usize,
    /// Fragment cache budget, bytes. `0` (the default) disables
    /// fragment extraction/serving. Both caches account their bytes in
    /// one gateway-side [`crate::memory::MemoryGovernor`]; a refused
    /// reservation grow evicts LRU entries, it never wedges a query.
    pub fragment_cache_bytes: usize,

    // ---- gateway session layer (see `crate::cluster::session`)
    /// Per-query execution deadline, ms (was hardcoded to 300 s in the
    /// gateway). Sessions can override it per submission via
    /// `SessionOpts::timeout`. Must be >= 1.
    pub query_timeout_ms: u64,
    /// Aggregate scan-footprint budget for concurrently admitted
    /// queries, bytes. `0` (the default) uses `device_capacity`, so
    /// admission mirrors per-worker governor headroom.
    pub admission_capacity_bytes: usize,
    /// Starvation bound for the admission queue: a waiting query is
    /// overtaken by at most this many later, higher-priority admissions
    /// before it is served strictly next. Must be >= 1.
    pub admission_bypass_limit: usize,

    // ---- fault recovery (see FAULTS.md)
    /// Max attempts per object-store read (transient failures only —
    /// permanent errors never retry). Values below 1 behave as 1: the
    /// read always runs at least once. Default 3.
    pub storage_retry_limit: usize,
    /// Base of the capped exponential backoff between storage retry
    /// attempts, ms (the sleep before attempt `n+1` is roughly
    /// `base * 2^(n-1)` plus deterministic jitter, capped at 32x base).
    /// `0` retries immediately. Default 10.
    pub storage_backoff_base_ms: u64,
    /// Gateway re-runs after a query fails with a *transient* error
    /// (injected fault, dropped connection) — op-level retries already
    /// exhausted. Each re-run mints a fresh query id over torn-down
    /// state. `0` turns query-level retry off. Default 2.
    pub query_retry_limit: usize,

    // ---- network executor
    /// Compress batches before sending (Fig-4 B, E toggles this).
    pub net_compression: Option<Codec>,
    /// Wire transport: in-process channels or real TCP sockets.
    pub transport: TransportKind,
    /// Reject inbound frames whose length prefix claims more than this
    /// many bytes (header + payload). Length fields arrive from the
    /// wire — corrupt or hostile values must not size receive buffers.
    pub max_frame_bytes: usize,

    // ---- pre-load executor (§3.3.3; Fig-4 H, I)
    /// Coalesce and prefetch scan byte ranges ahead of execution.
    pub byte_range_preload: bool,
    /// Warm upcoming task inputs into host memory ahead of dispatch.
    pub task_preload: bool,
    /// Coalesce byte ranges closer than this many bytes.
    pub coalesce_gap: u64,

    // ---- storage
    /// Datasource implementation scans use (§3.3.4, Fig-4 F→G).
    pub datasource: DatasourceKind,

    // ---- simulation
    /// Simulated hardware speeds (on-prem / cloud / test).
    pub profile: HwProfile,
    /// Simulated-time multiplier; `0` disables simulated delays.
    pub time_scale: f64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            num_workers: 1,
            compute_threads: 2,
            memory_threads: 1,
            preload_threads: 1,
            network_threads: 1,
            device_capacity: 256 << 20,
            pinned_pool: true,
            pinned_buf_size: 256 << 10,
            pinned_buffers: 256,
            spill_watermark: 0.85,
            promote_watermark: 0.70,
            urgency_reservation: 1_000_000,
            urgency_watermark: 100_000,
            residency_bonus_device: 0,
            residency_penalty_spilled: 0,
            residency_rerank_batch: 32,
            spill_codec: Codec::None,
            spill_segment_bytes: crate::memory::spill::DEFAULT_SEGMENT_BYTES,
            reservation_timeout_ms: 10_000,
            batch_rows: 8192,
            broadcast_threshold: 256 << 10,
            exchange_estimate_batches: 4,
            exchange_flush_bytes: 4 << 20,
            exchange_flush_floor_bytes: 64 << 10,
            exchange_flush_ceiling_bytes: 4 << 20,
            exchange_initial_credits: 32,
            result_cache_bytes: 0,
            fragment_cache_bytes: 0,
            query_timeout_ms: 300_000,
            admission_capacity_bytes: 0,
            admission_bypass_limit: 4,
            storage_retry_limit: 3,
            storage_backoff_base_ms: 10,
            query_retry_limit: 2,
            net_compression: Some(Codec::Zstd { level: 1 }),
            transport: TransportKind::Inproc,
            max_frame_bytes: crate::network::frame::DEFAULT_MAX_FRAME_BYTES,
            byte_range_preload: true,
            task_preload: true,
            coalesce_gap: 1 << 20,
            datasource: DatasourceKind::Custom,
            profile: HwProfile::test(),
            time_scale: 0.0,
        }
    }
}

impl WorkerConfig {
    /// Minimal config for unit tests: tiny memory, instant simulation.
    pub fn test() -> Self {
        WorkerConfig {
            device_capacity: 64 << 20,
            pinned_buf_size: 64 << 10,
            pinned_buffers: 128,
            ..Default::default()
        }
    }

    // --------------------------------------------------- Fig-4 presets

    /// On-prem config A: no pinned pool, no net compression, TCP.
    pub fn fig4_a() -> Self {
        WorkerConfig {
            pinned_pool: false,
            net_compression: None,
            transport: TransportKind::Tcp,
            byte_range_preload: false,
            task_preload: true,
            profile: HwProfile::on_prem(),
            ..Default::default()
        }
    }

    /// B = A + network compression.
    pub fn fig4_b() -> Self {
        WorkerConfig { net_compression: Some(Codec::Zstd { level: 1 }), ..Self::fig4_a() }
    }

    /// C = B + pinned fixed-size buffer pool.
    pub fn fig4_c() -> Self {
        WorkerConfig { pinned_pool: true, ..Self::fig4_b() }
    }

    /// D = C + GPUDirect RDMA transport.
    pub fn fig4_d() -> Self {
        WorkerConfig { transport: TransportKind::Rdma, ..Self::fig4_c() }
    }

    /// E = D − compression (free the CPU cycles; Fig-4's final win).
    pub fn fig4_e() -> Self {
        WorkerConfig { net_compression: None, ..Self::fig4_d() }
    }

    /// Cloud config F: generic datasource, no pre-loading.
    pub fn fig4_f() -> Self {
        WorkerConfig {
            datasource: DatasourceKind::Generic,
            byte_range_preload: false,
            task_preload: false,
            transport: TransportKind::Tcp,
            profile: HwProfile::cloud(),
            ..Default::default()
        }
    }

    /// G = F with the custom object-store datasource.
    pub fn fig4_g() -> Self {
        WorkerConfig { datasource: DatasourceKind::Custom, ..Self::fig4_f() }
    }

    /// H = G + byte-range pre-loading.
    pub fn fig4_h() -> Self {
        WorkerConfig { byte_range_preload: true, ..Self::fig4_g() }
    }

    /// I = H + compute-task pre-loading.
    pub fn fig4_i() -> Self {
        WorkerConfig { task_preload: true, ..Self::fig4_h() }
    }

    /// Look a preset up by its Figure-4 letter.
    pub fn preset(letter: char) -> Result<Self> {
        Ok(match letter.to_ascii_uppercase() {
            'A' => Self::fig4_a(),
            'B' => Self::fig4_b(),
            'C' => Self::fig4_c(),
            'D' => Self::fig4_d(),
            'E' => Self::fig4_e(),
            'F' => Self::fig4_f(),
            'G' => Self::fig4_g(),
            'H' => Self::fig4_h(),
            'I' => Self::fig4_i(),
            c => return Err(Error::Config(format!("unknown preset '{c}'"))),
        })
    }

    /// Apply `key = value` overrides from a parsed TOML-lite document.
    /// Recognized keys mirror the field names; `[worker]` section is
    /// optional.
    pub fn apply(&mut self, doc: &TomlLite) -> Result<()> {
        let get = |k: &str| doc.get("worker", k).or_else(|| doc.get("", k));
        macro_rules! set_usize {
            ($field:ident) => {
                if let Some(v) = get(stringify!($field)) {
                    self.$field = v.as_int()? as usize;
                }
            };
        }
        set_usize!(num_workers);
        set_usize!(compute_threads);
        set_usize!(memory_threads);
        set_usize!(preload_threads);
        set_usize!(network_threads);
        set_usize!(device_capacity);
        set_usize!(pinned_buf_size);
        set_usize!(pinned_buffers);
        set_usize!(batch_rows);
        set_usize!(broadcast_threshold);
        set_usize!(exchange_estimate_batches);
        set_usize!(exchange_flush_bytes);
        set_usize!(exchange_flush_floor_bytes);
        set_usize!(exchange_flush_ceiling_bytes);
        set_usize!(exchange_initial_credits);
        set_usize!(result_cache_bytes);
        set_usize!(fragment_cache_bytes);
        set_usize!(admission_capacity_bytes);
        set_usize!(admission_bypass_limit);
        if let Some(v) = get("query_timeout_ms") {
            self.query_timeout_ms = v.as_int()? as u64;
        }
        set_usize!(storage_retry_limit);
        set_usize!(query_retry_limit);
        if let Some(v) = get("storage_backoff_base_ms") {
            self.storage_backoff_base_ms = v.as_int()? as u64;
        }
        if let Some(v) = get("pinned_pool") {
            self.pinned_pool = v.as_bool()?;
        }
        if let Some(v) = get("spill_watermark") {
            self.spill_watermark = v.as_float()?;
        }
        if let Some(v) = get("promote_watermark") {
            self.promote_watermark = v.as_float()?;
        }
        if let Some(v) = get("urgency_reservation") {
            self.urgency_reservation = v.as_int()?;
        }
        if let Some(v) = get("urgency_watermark") {
            self.urgency_watermark = v.as_int()?;
        }
        if let Some(v) = get("residency_bonus_device") {
            self.residency_bonus_device = v.as_int()?;
        }
        if let Some(v) = get("residency_penalty_spilled") {
            self.residency_penalty_spilled = v.as_int()?;
        }
        set_usize!(residency_rerank_batch);
        if let Some(v) = get("spill_segment_bytes") {
            self.spill_segment_bytes = v.as_int()? as u64;
        }
        if let Some(v) = get("time_scale") {
            self.time_scale = v.as_float()?;
        }
        if let Some(v) = get("reservation_timeout_ms") {
            self.reservation_timeout_ms = v.as_int()? as u64;
        }
        if let Some(v) = get("coalesce_gap") {
            self.coalesce_gap = v.as_int()? as u64;
        }
        if let Some(v) = get("byte_range_preload") {
            self.byte_range_preload = v.as_bool()?;
        }
        if let Some(v) = get("task_preload") {
            self.task_preload = v.as_bool()?;
        }
        set_usize!(max_frame_bytes);
        // The *default* flush thresholds follow an overridden frame cap
        // down, so a file that shrinks only max_frame_bytes keeps
        // working (explicit values are still validated strictly below).
        // This must run after max_frame_bytes itself is applied — the
        // clamp target is the overridden cap, not the default.
        if get("exchange_flush_bytes").is_none() {
            self.exchange_flush_bytes =
                self.exchange_flush_bytes.min(self.max_frame_bytes / 2).max(1);
        }
        if get("exchange_flush_ceiling_bytes").is_none() {
            self.exchange_flush_ceiling_bytes =
                self.exchange_flush_ceiling_bytes.min(self.max_frame_bytes / 2).max(1);
        }
        if get("exchange_flush_floor_bytes").is_none() {
            self.exchange_flush_floor_bytes =
                self.exchange_flush_floor_bytes.min(self.exchange_flush_ceiling_bytes);
        }
        if let Some(v) = get("transport") {
            self.transport = TransportKind::parse(&v.as_str()?)?;
        }
        if let Some(v) = get("datasource") {
            self.datasource = DatasourceKind::parse(&v.as_str()?)?;
        }
        if let Some(v) = get("net_compression") {
            self.net_compression = match v.as_str()?.as_str() {
                "none" | "off" => None,
                "zstd" => Some(Codec::Zstd { level: 1 }),
                "lz4" | "lz4like" => Some(Codec::Lz4Like),
                other => {
                    return Err(Error::Config(format!("unknown codec '{other}'")))
                }
            };
        }
        if let Some(v) = get("spill_codec") {
            self.spill_codec = match v.as_str()?.as_str() {
                "none" | "off" => Codec::None,
                "zstd" => Codec::Zstd { level: 1 },
                "lz4" | "lz4like" => Codec::Lz4Like,
                other => {
                    return Err(Error::Config(format!("unknown codec '{other}'")))
                }
            };
        }
        if let Some(v) = get("profile") {
            self.profile = match v.as_str()?.as_str() {
                "on-prem" | "on_prem" => HwProfile::on_prem(),
                "cloud" => HwProfile::cloud(),
                "test" => HwProfile::test(),
                other => {
                    return Err(Error::Config(format!("unknown profile '{other}'")))
                }
            };
        }
        self.validate()
    }

    /// Load from a TOML-lite file.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{path}: {e}")))?;
        let doc = TomlLite::parse(&text)?;
        let mut cfg = WorkerConfig::default();
        cfg.apply(&doc)?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.num_workers == 0 {
            return Err(Error::Config("num_workers must be >= 1".into()));
        }
        if self.compute_threads == 0 {
            return Err(Error::Config("compute_threads must be >= 1".into()));
        }
        if self.memory_threads == 0 {
            return Err(Error::Config("memory_threads must be >= 1".into()));
        }
        if self.preload_threads == 0 {
            return Err(Error::Config("preload_threads must be >= 1".into()));
        }
        if self.network_threads == 0 {
            return Err(Error::Config("network_threads must be >= 1".into()));
        }
        if self.device_capacity == 0 {
            return Err(Error::Config(
                "device_capacity must be >= 1 (a zero-byte device admits no \
                 allocation and wedges the first reservation)"
                    .into(),
            ));
        }
        if self.reservation_timeout_ms == 0 {
            return Err(Error::Config(
                "reservation_timeout_ms must be >= 1 (a zero deadline fails \
                 every blocked reservation before demotion can run)"
                    .into(),
            ));
        }
        if !(self.time_scale >= 0.0) || !self.time_scale.is_finite() {
            return Err(Error::Config(
                "time_scale must be finite and >= 0 (0 disables simulated \
                 delays)"
                    .into(),
            ));
        }
        if !(0.0..=1.0).contains(&self.spill_watermark) {
            return Err(Error::Config("spill_watermark must be in [0,1]".into()));
        }
        if !(0.0..=1.0).contains(&self.promote_watermark) {
            return Err(Error::Config("promote_watermark must be in [0,1]".into()));
        }
        if self.spill_segment_bytes == 0 {
            return Err(Error::Config("spill_segment_bytes must be >= 1".into()));
        }
        if self.residency_bonus_device < 0 || self.residency_penalty_spilled < 0 {
            return Err(Error::Config(
                "residency bonus/penalty must be >= 0 (a negative bonus would let \
                 spilled-input tasks outrank device-resident ones)"
                    .into(),
            ));
        }
        if self.residency_rerank_batch == 0 {
            return Err(Error::Config("residency_rerank_batch must be >= 1".into()));
        }
        if self.batch_rows == 0 {
            return Err(Error::Config("batch_rows must be >= 1".into()));
        }
        if self.exchange_estimate_batches == 0 {
            return Err(Error::Config(
                "exchange_estimate_batches must be >= 1 (0 would broadcast a \
                 zero-byte estimate before seeing any data and force Broadcast \
                 mode for arbitrarily large build sides)"
                    .into(),
            ));
        }
        if self.exchange_flush_bytes == 0 {
            return Err(Error::Config(
                "exchange_flush_bytes must be >= 1 (1 = flush every batch, \
                 i.e. coalescing off)"
                    .into(),
            ));
        }
        // A coalesced flush can overshoot the threshold by the last
        // appended batch's share, and the frame adds header/prelude
        // bytes — require 2x headroom so every shuffle frame clears the
        // receiver's max_frame_bytes guard instead of dropping the
        // connection.
        if self.exchange_flush_bytes > self.max_frame_bytes / 2 {
            return Err(Error::Config(format!(
                "exchange_flush_bytes ({}) must be <= max_frame_bytes / 2 ({}): \
                 coalesced shuffle frames would exceed the receiver's frame \
                 limit and kill the connection",
                self.exchange_flush_bytes,
                self.max_frame_bytes / 2
            )));
        }
        if self.exchange_flush_floor_bytes == 0 {
            return Err(Error::Config(
                "exchange_flush_floor_bytes must be >= 1 (the adaptive \
                 controller's lower bound; 1 = congested paths flush every \
                 batch)"
                    .into(),
            ));
        }
        if self.exchange_flush_floor_bytes > self.exchange_flush_ceiling_bytes {
            return Err(Error::Config(format!(
                "exchange_flush_floor_bytes ({}) must be <= \
                 exchange_flush_ceiling_bytes ({}): the adaptive flush \
                 controller moves each destination's threshold inside \
                 [floor, ceiling]",
                self.exchange_flush_floor_bytes, self.exchange_flush_ceiling_bytes
            )));
        }
        if self.exchange_flush_ceiling_bytes > self.max_frame_bytes / 2 {
            return Err(Error::Config(format!(
                "exchange_flush_ceiling_bytes ({}) must be <= max_frame_bytes / 2 \
                 ({}): an adapted-up flush threshold needs the same overshoot \
                 headroom as exchange_flush_bytes",
                self.exchange_flush_ceiling_bytes,
                self.max_frame_bytes / 2
            )));
        }
        if self.exchange_initial_credits == 0 {
            return Err(Error::Config(
                "exchange_initial_credits must be >= 1 (a zero startup window \
                 could never send the first data frame)"
                    .into(),
            ));
        }
        for (name, bytes) in [
            ("result_cache_bytes", self.result_cache_bytes),
            ("fragment_cache_bytes", self.fragment_cache_bytes),
        ] {
            if bytes != 0 && bytes < 1024 {
                return Err(Error::Config(format!(
                    "{name} ({bytes}) must be 0 (cache off) or >= 1 KiB: a \
                     smaller budget cannot hold any result and every insert \
                     would be refused"
                )));
            }
        }
        if self.query_timeout_ms == 0 {
            return Err(Error::Config(
                "query_timeout_ms must be >= 1 (a zero deadline would expire \
                 every query before its first task runs)"
                    .into(),
            ));
        }
        if self.admission_bypass_limit == 0 {
            return Err(Error::Config(
                "admission_bypass_limit must be >= 1 (a zero bound makes the \
                 admission queue strictly FIFO across priorities, which \
                 defeats priority scheduling; use 1 for the tightest legal \
                 bound)"
                    .into(),
            ));
        }
        if self.pinned_pool && (self.pinned_buf_size == 0 || self.pinned_buffers == 0) {
            return Err(Error::Config("pinned pool dimensions must be >= 1".into()));
        }
        if self.max_frame_bytes < (1 << 16) {
            return Err(Error::Config(
                "max_frame_bytes must be >= 64 KiB (a tighter ceiling would reject \
                 ordinary batch frames)"
                    .into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_as_the_paper_describes() {
        let a = WorkerConfig::fig4_a();
        let b = WorkerConfig::fig4_b();
        let c = WorkerConfig::fig4_c();
        let d = WorkerConfig::fig4_d();
        let e = WorkerConfig::fig4_e();
        assert!(!a.pinned_pool && a.net_compression.is_none());
        assert!(b.net_compression.is_some());
        assert!(c.pinned_pool);
        assert_eq!(d.transport, TransportKind::Rdma);
        assert!(e.net_compression.is_none() && e.transport == TransportKind::Rdma);
    }

    #[test]
    fn cloud_presets_step_f_to_i() {
        let f = WorkerConfig::fig4_f();
        let g = WorkerConfig::fig4_g();
        let h = WorkerConfig::fig4_h();
        let i = WorkerConfig::fig4_i();
        assert_eq!(f.datasource, DatasourceKind::Generic);
        assert!(!f.byte_range_preload && !f.task_preload);
        assert_eq!(g.datasource, DatasourceKind::Custom);
        assert!(h.byte_range_preload && !h.task_preload);
        assert!(i.byte_range_preload && i.task_preload);
    }

    #[test]
    fn preset_lookup_by_letter() {
        assert!(WorkerConfig::preset('a').is_ok());
        assert!(WorkerConfig::preset('I').is_ok());
        assert!(WorkerConfig::preset('z').is_err());
    }

    #[test]
    fn apply_overrides() {
        let doc = TomlLite::parse(
            "[worker]\ncompute_threads = 7\ntransport = \"rdma\"\n\
             net_compression = \"none\"\nspill_watermark = 0.5\n\
             promote_watermark = 0.4\nspill_segment_bytes = 65536\n\
             urgency_reservation = 777\nurgency_watermark = 99\n\
             residency_bonus_device = 40\nresidency_penalty_spilled = 160\n\
             residency_rerank_batch = 8\nexchange_flush_bytes = 131072\n",
        )
        .unwrap();
        let mut cfg = WorkerConfig::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.exchange_flush_bytes, 128 << 10);
        assert_eq!(cfg.compute_threads, 7);
        assert_eq!(cfg.transport, TransportKind::Rdma);
        assert!(cfg.net_compression.is_none());
        assert_eq!(cfg.spill_watermark, 0.5);
        assert_eq!(cfg.promote_watermark, 0.4);
        assert_eq!(cfg.spill_segment_bytes, 65536);
        assert_eq!(cfg.urgency_reservation, 777);
        assert_eq!(cfg.urgency_watermark, 99);
        assert_eq!(cfg.residency_bonus_device, 40);
        assert_eq!(cfg.residency_penalty_spilled, 160);
        assert_eq!(cfg.residency_rerank_batch, 8);
    }

    #[test]
    fn residency_defaults_are_off_and_validated() {
        let cfg = WorkerConfig::default();
        assert_eq!(cfg.residency_bonus_device, 0, "feature off by default");
        assert_eq!(cfg.residency_penalty_spilled, 0);
        let mut cfg = WorkerConfig::default();
        cfg.residency_bonus_device = -5;
        assert!(cfg.validate().is_err());
        let mut cfg = WorkerConfig::default();
        cfg.residency_penalty_spilled = -1;
        assert!(cfg.validate().is_err());
        let mut cfg = WorkerConfig::default();
        cfg.residency_rerank_batch = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = WorkerConfig::default();
        cfg.num_workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = WorkerConfig::default();
        cfg.spill_watermark = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = WorkerConfig::default();
        cfg.promote_watermark = -0.1;
        assert!(cfg.validate().is_err());
        let mut cfg = WorkerConfig::default();
        cfg.spill_segment_bytes = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = WorkerConfig::default();
        cfg.max_frame_bytes = 1024;
        assert!(cfg.validate().is_err(), "frame ceiling below 64 KiB rejected");
        let mut cfg = WorkerConfig::default();
        cfg.exchange_flush_bytes = 0;
        assert!(cfg.validate().is_err());
        // a legal frame ceiling that the flush threshold would overrun
        let mut cfg = WorkerConfig::default();
        cfg.max_frame_bytes = 1 << 20; // 1 MiB: valid on its own
        assert!(
            cfg.validate().is_err(),
            "4 MiB default flush must be rejected against a 1 MiB frame cap"
        );
        cfg.exchange_flush_bytes = 256 << 10;
        assert!(cfg.validate().is_ok(), "flush within half the frame cap");
    }

    #[test]
    fn max_frame_bytes_defaults_and_overrides() {
        let cfg = WorkerConfig::default();
        assert_eq!(cfg.max_frame_bytes, crate::network::frame::DEFAULT_MAX_FRAME_BYTES);
        // shrinking only the frame cap keeps working: the default
        // flush threshold follows it down
        let doc = TomlLite::parse("max_frame_bytes = 1048576\n").unwrap();
        let mut cfg = WorkerConfig::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.max_frame_bytes, 1 << 20);
        assert_eq!(
            cfg.exchange_flush_bytes,
            512 << 10,
            "default flush clamps to half the shrunken frame cap"
        );
        assert_eq!(
            cfg.exchange_flush_ceiling_bytes,
            512 << 10,
            "default controller ceiling follows the frame cap down too"
        );
        assert_eq!(cfg.exchange_flush_floor_bytes, 64 << 10, "floor already fits");
        // an explicit flush above the cap is still rejected
        let doc = TomlLite::parse(
            "max_frame_bytes = 1048576\nexchange_flush_bytes = 4194304\n",
        )
        .unwrap();
        let mut cfg = WorkerConfig::default();
        assert!(cfg.apply(&doc).is_err());
        // and an explicit in-range flush applies verbatim
        let doc = TomlLite::parse(
            "max_frame_bytes = 1048576\nexchange_flush_bytes = 262144\n",
        )
        .unwrap();
        let mut cfg = WorkerConfig::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.exchange_flush_bytes, 256 << 10);
    }

    #[test]
    fn exchange_estimate_batches_validated() {
        let mut cfg = WorkerConfig::default();
        cfg.exchange_estimate_batches = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn flow_control_knobs_validated_and_applied() {
        // defaults are self-consistent
        let cfg = WorkerConfig::default();
        assert_eq!(cfg.exchange_flush_floor_bytes, 64 << 10);
        assert_eq!(cfg.exchange_flush_ceiling_bytes, 4 << 20);
        assert_eq!(cfg.exchange_initial_credits, 32);
        cfg.validate().unwrap();

        let mut cfg = WorkerConfig::default();
        cfg.exchange_flush_floor_bytes = 0;
        assert!(cfg.validate().is_err(), "zero floor rejected");

        let mut cfg = WorkerConfig::default();
        cfg.exchange_flush_floor_bytes = 8 << 20; // above the ceiling
        assert!(cfg.validate().is_err(), "floor above ceiling rejected");

        let mut cfg = WorkerConfig::default();
        cfg.exchange_flush_ceiling_bytes = cfg.max_frame_bytes; // > cap/2
        assert!(cfg.validate().is_err(), "ceiling above max_frame_bytes/2 rejected");

        let mut cfg = WorkerConfig::default();
        cfg.exchange_initial_credits = 0;
        assert!(cfg.validate().is_err(), "zero credit window rejected");

        // floor == ceiling (adaptation pinned) is legal
        let mut cfg = WorkerConfig::default();
        cfg.exchange_flush_floor_bytes = 1 << 20;
        cfg.exchange_flush_ceiling_bytes = 1 << 20;
        cfg.validate().unwrap();

        // file overrides reach the fields, and an explicit out-of-range
        // ceiling is a hard error (no silent clamping of explicit values)
        let doc = TomlLite::parse(
            "exchange_flush_floor_bytes = 4096\n\
             exchange_flush_ceiling_bytes = 1048576\n\
             exchange_initial_credits = 4\n",
        )
        .unwrap();
        let mut cfg = WorkerConfig::default();
        cfg.exchange_flush_bytes = 512 << 10;
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.exchange_flush_floor_bytes, 4096);
        assert_eq!(cfg.exchange_flush_ceiling_bytes, 1 << 20);
        assert_eq!(cfg.exchange_initial_credits, 4);
        let doc = TomlLite::parse(
            "max_frame_bytes = 1048576\nexchange_flush_ceiling_bytes = 4194304\n",
        )
        .unwrap();
        let mut cfg = WorkerConfig::default();
        assert!(cfg.apply(&doc).is_err());
    }

    #[test]
    fn cache_knobs_default_off_and_validate() {
        let cfg = WorkerConfig::default();
        assert_eq!(cfg.result_cache_bytes, 0, "serving cache off by default");
        assert_eq!(cfg.fragment_cache_bytes, 0);
        cfg.validate().unwrap();
        let doc = TomlLite::parse(
            "result_cache_bytes = 1048576\nfragment_cache_bytes = 2097152\n",
        )
        .unwrap();
        let mut cfg = WorkerConfig::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.result_cache_bytes, 1 << 20);
        assert_eq!(cfg.fragment_cache_bytes, 2 << 20);
        let mut cfg = WorkerConfig::default();
        cfg.result_cache_bytes = 100; // nonzero but below any useful size
        assert!(cfg.validate().is_err());
        let mut cfg = WorkerConfig::default();
        cfg.fragment_cache_bytes = 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn session_knobs_default_and_validate() {
        let cfg = WorkerConfig::default();
        assert_eq!(cfg.query_timeout_ms, 300_000, "matches the old hardcoded 300 s");
        assert_eq!(cfg.admission_capacity_bytes, 0, "0 = device_capacity");
        assert_eq!(cfg.admission_bypass_limit, 4);
        cfg.validate().unwrap();
        let doc = TomlLite::parse(
            "query_timeout_ms = 1500\nadmission_capacity_bytes = 1048576\n\
             admission_bypass_limit = 2\n",
        )
        .unwrap();
        let mut cfg = WorkerConfig::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.query_timeout_ms, 1500);
        assert_eq!(cfg.admission_capacity_bytes, 1 << 20);
        assert_eq!(cfg.admission_bypass_limit, 2);
        let mut cfg = WorkerConfig::default();
        cfg.query_timeout_ms = 0;
        assert!(cfg.validate().is_err(), "zero deadline rejected");
        let mut cfg = WorkerConfig::default();
        cfg.admission_bypass_limit = 0;
        assert!(cfg.validate().is_err(), "zero bypass bound rejected");
    }

    #[test]
    fn fault_recovery_knobs_default_and_apply() {
        let cfg = WorkerConfig::default();
        assert_eq!(cfg.storage_retry_limit, 3);
        assert_eq!(cfg.storage_backoff_base_ms, 10);
        assert_eq!(cfg.query_retry_limit, 2);
        cfg.validate().unwrap();
        let doc = TomlLite::parse(
            "storage_retry_limit = 5\nstorage_backoff_base_ms = 0\n\
             query_retry_limit = 0\n",
        )
        .unwrap();
        let mut cfg = WorkerConfig::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.storage_retry_limit, 5);
        assert_eq!(cfg.storage_backoff_base_ms, 0, "0 = retry immediately");
        assert_eq!(cfg.query_retry_limit, 0, "0 = query-level retry off");
        // every value is legal: 0 attempts behaves as 1, large values
        // just mean more patience — validate() has nothing to reject
        let mut cfg = WorkerConfig::default();
        cfg.storage_retry_limit = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn bad_enum_values_are_config_errors() {
        let doc = TomlLite::parse("transport = \"carrier-pigeon\"\n").unwrap();
        let mut cfg = WorkerConfig::default();
        assert!(matches!(cfg.apply(&doc), Err(Error::Config(_))));
    }
}
