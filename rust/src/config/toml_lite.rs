//! TOML-subset parser: sections, `key = value`, strings / ints /
//! floats / bools, `#` comments. Enough for worker config files without
//! an offline dependency.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed scalar.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<String> {
        match self {
            TomlValue::Str(s) => Ok(s.clone()),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(v) => Ok(*v),
            other => Err(Error::Config(format!("expected int, got {other:?}"))),
        }
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(v) => Ok(*v),
            TomlValue::Int(v) => Ok(*v as f64),
            other => Err(Error::Config(format!("expected float, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(v) => Ok(*v),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }
}

/// Parsed document: `(section, key) -> value`. Keys outside any section
/// use the empty-string section.
#[derive(Clone, Debug, Default)]
pub struct TomlLite {
    entries: BTreeMap<(String, String), TomlValue>,
}

impl TomlLite {
    pub fn parse(text: &str) -> Result<TomlLite> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config(format!(
                        "line {}: unterminated section header",
                        lineno + 1
                    )));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = key.trim().to_string();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            let value = parse_value(value.trim()).map_err(|e| {
                Error::Config(format!("line {}: {e}", lineno + 1))
            })?;
            entries.insert((section.clone(), key), value);
        }
        Ok(TomlLite { entries })
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All keys of a section (introspection / error messages).
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string is not a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err(format!("unterminated string: {s}"));
        }
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    // ints may use _ separators, like TOML
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(v) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(format!("cannot parse value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_scalar_kinds() {
        let doc = TomlLite::parse(
            "name = \"theseus\"\nthreads = 8\nscale = 0.25\nfast = true\nbig = 1_000_000\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str().unwrap(), "theseus");
        assert_eq!(doc.get("", "threads").unwrap().as_int().unwrap(), 8);
        assert_eq!(doc.get("", "scale").unwrap().as_float().unwrap(), 0.25);
        assert!(doc.get("", "fast").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("", "big").unwrap().as_int().unwrap(), 1_000_000);
    }

    #[test]
    fn sections_scope_keys() {
        let doc = TomlLite::parse("a = 1\n[worker]\na = 2\n[net]\na = 3\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("worker", "a").unwrap().as_int().unwrap(), 2);
        assert_eq!(doc.get("net", "a").unwrap().as_int().unwrap(), 3);
        assert!(doc.get("worker", "b").is_none());
    }

    #[test]
    fn comments_stripped_except_in_strings() {
        let doc =
            TomlLite::parse("x = 1 # comment\ns = \"a # b\" # real comment\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("", "s").unwrap().as_str().unwrap(), "a # b");
    }

    #[test]
    fn int_coerces_to_float_not_reverse() {
        let doc = TomlLite::parse("i = 3\nf = 3.5\n").unwrap();
        assert_eq!(doc.get("", "i").unwrap().as_float().unwrap(), 3.0);
        assert!(doc.get("", "f").unwrap().as_int().is_err());
    }

    #[test]
    fn malformed_lines_error_with_line_numbers() {
        for bad in ["just words\n", "[unterminated\n", "x = \n", "= 3\n"] {
            let e = TomlLite::parse(bad).unwrap_err().to_string();
            assert!(e.contains("line 1"), "{bad:?} -> {e}");
        }
    }

    #[test]
    fn keys_listing() {
        let doc = TomlLite::parse("[w]\nb = 1\na = 2\n").unwrap();
        assert_eq!(doc.keys("w"), vec!["a", "b"]);
        assert!(doc.keys("nope").is_empty());
    }
}
