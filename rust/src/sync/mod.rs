//! Concurrency-invariant enforcement (runtime half).
//!
//! The declared lock hierarchy lives in `rust/lockorder.toml`; the
//! static half is the `cargo xtask lint` pass (see `rust/xtask/`),
//! which checks every `Mutex`/`RwLock`/`Condvar` in this crate against
//! the same declarations. `CONCURRENCY.md` at the repo root documents
//! the full rank table and the wait/notify pairings.

pub mod ordered;
pub mod ranks;

pub use ordered::{
    poison_recovered_total, publish_metrics, OrderedCondvar, OrderedGuard,
    OrderedMutex,
};
