//! Ordered lock primitives — the runtime twin of `cargo xtask lint`.
//!
//! [`OrderedMutex`] and [`OrderedCondvar`] wrap their `std::sync`
//! counterparts with the lock hierarchy declared in
//! `rust/lockorder.toml` (ranks re-exported as constants from
//! [`crate::sync::ranks`]). Two guarantees ride on them:
//!
//! 1. **Debug-time order checking.** Under `debug_assertions` every
//!    acquisition pushes its rank onto a thread-local held-rank stack
//!    and panics if the new rank is not strictly greater than every
//!    rank already held — the exact inversion class the static lint
//!    (L1) checks for, enforced dynamically on whatever path the tests
//!    actually execute. Release builds compile the stack away; the
//!    wrappers are passthrough (`PERF.md` pins micro benches #7/#9 as
//!    the no-regression witnesses).
//! 2. **Poison containment (all builds).** A contained
//!    [`crate::Error::WorkerPanic`] can leave a shared control-plane
//!    mutex poisoned even though the cluster survives the panic.
//!    `lock()` recovers the poisoned state instead of unwrap-
//!    propagating, counts the recovery (exported as the
//!    `sync.poison_recovered_total` counter), and logs the lock name.
//!    The protected values are designed to stay consistent across a
//!    holder panic: every migrated critical section either performs a
//!    single-assignment update or re-validates its predicate under the
//!    lock.
//!
//! **Condvar discipline is structural here:** `OrderedCondvar::notify_*`
//! take a reference to the paired lock's guard, so a notify that does
//! not hold the mutex is a compile error — the lost-wakeup class PR 6
//! fixed by hand in `Outbox::grant_credits` cannot be reintroduced on a
//! migrated lock. The static lint (L2) covers the raw `Condvar`s that
//! remain.
//!
//! **Scope.** The checker only sees `OrderedMutex` acquisitions: a raw
//! `Mutex` taken between two ordered ones is invisible to the runtime
//! stack (the static lint ranks those via `lockorder.toml` instead).
//! There is deliberately no `OrderedRwLock` — every lock in the
//! migrated control-plane set is a `Mutex`.

use std::cell::{Cell, RefCell};
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Total poisoned-lock recoveries since process start (all
/// `OrderedMutex`/`OrderedCondvar` instances).
static POISON_RECOVERED: AtomicU64 = AtomicU64::new(0);
/// What `publish_metrics` has already folded into a `Metrics` counter.
static POISON_PUBLISHED: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of poisoned-lock recoveries.
pub fn poison_recovered_total() -> u64 {
    POISON_RECOVERED.load(Ordering::Relaxed)
}

/// Fold recoveries since the last publish into the
/// `sync.poison_recovered_total` counter (monotone: publishes deltas).
pub fn publish_metrics(m: &crate::metrics::Metrics) {
    let total = POISON_RECOVERED.load(Ordering::Relaxed);
    let last = POISON_PUBLISHED.swap(total, Ordering::Relaxed);
    if total > last {
        m.counter("sync.poison_recovered_total").add(total - last);
    }
}

fn note_poison(name: &str) {
    POISON_RECOVERED.fetch_add(1, Ordering::Relaxed);
    log::warn!("recovered poisoned lock `{name}` (a holder thread panicked)");
}

#[cfg(debug_assertions)]
thread_local! {
    /// (rank, name, token) per lock currently held by this thread.
    static HELD: RefCell<Vec<(u16, &'static str, u64)>> =
        const { RefCell::new(Vec::new()) };
    /// Per-acquisition token source, so guards dropped out of creation
    /// order release the right stack entry.
    static NEXT_TOKEN: Cell<u64> = const { Cell::new(0) };
}

#[cfg(debug_assertions)]
fn push_rank(rank: u16, name: &'static str) -> u64 {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some((top_rank, top_name, _)) =
            held.iter().max_by_key(|(r, _, _)| *r)
        {
            assert!(
                *top_rank < rank,
                "lock-order inversion: acquiring `{name}` (rank {rank}) while \
                 holding `{top_name}` (rank {top_rank}); the declared \
                 hierarchy lives in rust/lockorder.toml"
            );
        }
        let token = NEXT_TOKEN.with(|t| {
            let v = t.get().wrapping_add(1);
            t.set(v);
            v
        });
        held.push((rank, name, token));
        token
    })
}

#[cfg(debug_assertions)]
fn pop_rank(token: u64) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(i) = held.iter().rposition(|(_, _, t)| *t == token) {
            held.remove(i);
        }
    });
}

/// A `Mutex` with a declared position in the global lock hierarchy.
pub struct OrderedMutex<T> {
    // lint: lock-ok(the wrapper itself; its rank arrives per-instance via new())
    inner: Mutex<T>,
    rank: u16,
    name: &'static str,
}

impl<T> OrderedMutex<T> {
    /// Wrap `value` at `rank` (a constant from [`crate::sync::ranks`]).
    pub const fn new(rank: u16, name: &'static str, value: T) -> Self {
        OrderedMutex { inner: Mutex::new(value), rank, name }
    }

    /// Acquire. Panics (debug builds only) on a rank inversion;
    /// recovers poison in all builds.
    pub fn lock(&self) -> OrderedGuard<'_, T> {
        #[cfg(debug_assertions)]
        let token = push_rank(self.rank, self.name);
        let guard = self.inner.lock().unwrap_or_else(|p| {
            note_poison(self.name);
            p.into_inner()
        });
        OrderedGuard {
            guard: Some(guard),
            lock: self,
            #[cfg(debug_assertions)]
            token,
        }
    }

    /// The declared rank (tests and diagnostics).
    pub fn rank(&self) -> u16 {
        self.rank
    }

    /// The declared hierarchy name.
    pub fn name(&self) -> &'static str {
        self.name
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("name", &self.name)
            .field("rank", &self.rank)
            .field("inner", &self.inner)
            .finish()
    }
}

/// RAII guard for an [`OrderedMutex`]; releases the held-rank entry on
/// drop.
pub struct OrderedGuard<'a, T> {
    /// `Some` except transiently while parked in an
    /// [`OrderedCondvar`] wait.
    guard: Option<MutexGuard<'a, T>>,
    lock: &'a OrderedMutex<T>,
    #[cfg(debug_assertions)]
    token: u64,
}

impl<T> Deref for OrderedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard present")
    }
}

impl<T> DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard present")
    }
}

impl<T> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        pop_rank(self.token);
    }
}

/// A `Condvar` paired with one [`OrderedMutex`]. Waits release and
/// re-take the held-rank entry around the park; notifies demand the
/// paired guard by reference, making notify-while-held structural.
#[derive(Default)]
pub struct OrderedCondvar {
    // lint: lock-ok(the wrapper itself; pairing is per-instance, enforced by the guard-taking API)
    cv: Condvar,
}

impl OrderedCondvar {
    pub const fn new() -> Self {
        OrderedCondvar { cv: Condvar::new() }
    }

    /// Block until notified. Callers loop on their predicate (lint L2
    /// checks this for raw condvars; the pattern is the same here).
    pub fn wait<'a, T>(&self, g: OrderedGuard<'a, T>) -> OrderedGuard<'a, T> {
        let lock = g.lock;
        let inner = Self::detach(g);
        let inner = self.cv.wait(inner).unwrap_or_else(|p| {
            note_poison(lock.name);
            p.into_inner()
        });
        Self::reattach(lock, inner)
    }

    /// Block until notified or `dur` elapses; returns the re-acquired
    /// guard and whether the wait timed out.
    pub fn wait_timeout<'a, T>(
        &self,
        g: OrderedGuard<'a, T>,
        dur: Duration,
    ) -> (OrderedGuard<'a, T>, bool) {
        let lock = g.lock;
        let inner = Self::detach(g);
        let (inner, res) = self.cv.wait_timeout(inner, dur).unwrap_or_else(|p| {
            note_poison(lock.name);
            p.into_inner()
        });
        (Self::reattach(lock, inner), res.timed_out())
    }

    /// Wake one waiter. `_held` proves the paired mutex is held at the
    /// notify, so the waiter's predicate check cannot race the state
    /// change (the lost-wakeup class).
    pub fn notify_one<T>(&self, _held: &OrderedGuard<'_, T>) {
        self.cv.notify_one();
    }

    /// Wake all waiters; same held-guard contract as [`Self::notify_one`].
    pub fn notify_all<T>(&self, _held: &OrderedGuard<'_, T>) {
        self.cv.notify_all();
    }

    /// Take the inner `MutexGuard` out of `g`, dropping its held-rank
    /// entry without unlocking.
    fn detach<'a, T>(mut g: OrderedGuard<'a, T>) -> MutexGuard<'a, T> {
        #[cfg(debug_assertions)]
        pop_rank(g.token);
        let inner = g.guard.take().expect("guard present");
        std::mem::forget(g);
        inner
    }

    /// Re-wrap a `MutexGuard` returned by the condvar, re-pushing the
    /// rank (re-checked: waking while holding a higher rank is the same
    /// inversion as acquiring fresh).
    fn reattach<'a, T>(
        lock: &'a OrderedMutex<T>,
        inner: MutexGuard<'a, T>,
    ) -> OrderedGuard<'a, T> {
        #[cfg(debug_assertions)]
        let token = push_rank(lock.rank, lock.name);
        OrderedGuard {
            guard: Some(inner),
            lock,
            #[cfg(debug_assertions)]
            token,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_unlock_roundtrip() {
        let m = OrderedMutex::new(10, "test.a", 1u32);
        {
            let mut g = m.lock();
            *g += 1;
        }
        assert_eq!(*m.lock(), 2);
        assert_eq!(m.rank(), 10);
        assert_eq!(m.name(), "test.a");
    }

    #[test]
    fn ascending_ranks_are_legal() {
        let a = OrderedMutex::new(10, "test.low", ());
        let b = OrderedMutex::new(20, "test.high", ());
        let ga = a.lock();
        let gb = b.lock();
        drop(gb);
        drop(ga);
        // out-of-order guard drops release the right stack entries
        let ga = a.lock();
        let gb = b.lock();
        drop(ga);
        drop(gb);
        let _again = a.lock();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn inversion_panics_under_debug_assertions() {
        let result = std::thread::spawn(|| {
            let low = OrderedMutex::new(10, "test.low2", ());
            let high = OrderedMutex::new(20, "test.high2", ());
            let _gh = high.lock();
            let _gl = low.lock(); // inversion: 10 acquired under 20
        })
        .join();
        let err = result.expect_err("seeded inversion must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(
            msg.contains("lock-order inversion"),
            "panic message names the inversion: {msg}"
        );
    }

    #[cfg(debug_assertions)]
    #[test]
    fn same_rank_nesting_panics_under_debug_assertions() {
        let result = std::thread::spawn(|| {
            let a = OrderedMutex::new(10, "test.shard_a", ());
            let b = OrderedMutex::new(10, "test.shard_b", ());
            let _ga = a.lock();
            let _gb = b.lock(); // shards share a rank: never nest them
        })
        .join();
        assert!(result.is_err(), "same-rank nesting must panic");
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn inversion_is_free_in_release() {
        // the held-rank stack compiles away: the same seeded inversion
        // that panics under debug_assertions is a plain nested lock here
        let low = OrderedMutex::new(10, "test.low_rel", ());
        let high = OrderedMutex::new(20, "test.high_rel", ());
        let _gh = high.lock();
        let _gl = low.lock();
    }

    #[test]
    fn condvar_wait_and_notify_while_held() {
        let pair = Arc::new((
            OrderedMutex::new(10, "test.cv_mutex", false),
            OrderedCondvar::new(),
        ));
        let waiter = {
            let pair = pair.clone();
            std::thread::spawn(move || {
                let (m, cv) = &*pair;
                let mut g = m.lock();
                let mut rounds = 0u32;
                while !*g {
                    let (g2, timed_out) =
                        cv.wait_timeout(g, Duration::from_millis(200));
                    g = g2;
                    rounds += 1;
                    if timed_out && rounds > 50 {
                        panic!("notify never arrived");
                    }
                }
            })
        };
        std::thread::sleep(Duration::from_millis(10));
        {
            let (m, cv) = &*pair;
            let mut g = m.lock();
            *g = true;
            cv.notify_all(&g); // state change and notify under one hold
        }
        waiter.join().expect("waiter saw the predicate");
    }

    #[test]
    fn poison_is_recovered_and_counted() {
        let before = poison_recovered_total();
        let m = Arc::new(OrderedMutex::new(10, "test.poisoned", 7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        // a raw Mutex would now return Err(Poisoned); OrderedMutex
        // recovers and counts
        assert_eq!(*m.lock(), 7);
        assert!(poison_recovered_total() > before);
        let metrics = crate::metrics::Metrics::default();
        publish_metrics(&metrics);
        assert!(metrics.counter_value("sync.poison_recovered_total") > 0);
    }

    #[test]
    fn waiting_releases_the_held_rank() {
        // while parked on a rank-20 condvar, acquiring rank 10 from
        // another context of the same thread is impossible — but other
        // threads' stacks are independent; here we check the waiter's
        // own stack is popped during the park by re-acquiring a lower
        // rank right after a timed-out wait returns the guard chain to
        // us in predicate order.
        let low = OrderedMutex::new(10, "test.low3", ());
        let high = OrderedMutex::new(20, "test.high3", ());
        let cv = OrderedCondvar::new();
        let g = high.lock();
        let (g, timed_out) = cv.wait_timeout(g, Duration::from_millis(1));
        assert!(timed_out);
        drop(g);
        let _gl = low.lock(); // stack empty again: legal
    }
}
