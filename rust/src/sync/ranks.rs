//! @generated from rust/lockorder.toml — do not edit values by hand.
//!
//! One constant per `runtime = true` lock in `rust/lockorder.toml`,
//! named by uppercasing the lock's hierarchy name (`.` → `_`).
//! `cargo xtask lint` verifies this table matches the declarations
//! (same set of names, same rank values) and fails CI on drift, so the
//! static pass and the runtime checker can never enforce two different
//! hierarchies.
//!
//! Lower rank = acquired earlier (outermost). A thread may only
//! acquire an [`crate::sync::OrderedMutex`] whose rank is strictly
//! greater than every rank it already holds.

/// `FaultInjector.install` — serializes fault-plan installers
/// process-wide.
/// Rank 0 territory: a `FaultScope` holds it across whole test bodies,
/// so every other lock in the crate must rank above it.
pub const FAULT_INSTALL: u16 = 10;
/// `CtrlInner.state` — admission-controller queue + ready set.
pub const ADMISSION_STATE: u16 = 100;
/// `ServingCache.results` — exact-result LRU.
pub const CACHE_RESULTS: u16 = 110;
/// `ServingCache.fragments` — fragment LRU.
pub const CACHE_FRAGMENTS: u16 = 112;
/// `ServingCache.plans` — plan-compile memo.
pub const CACHE_PLANS: u16 = 114;
/// `TaskQueue.heap` — compute-ready priority heap.
pub const SCHED_HEAP: u16 = 120;
/// `TaskQueue.listeners` — pressure events poked on submit.
pub const SCHED_LISTENERS: u16 = 124;
/// `TaskQueue.dirty_holders` — residency re-rank dirty set.
pub const SCHED_DIRTY_HOLDERS: u16 = 128;
/// `HolderRegistry.holders` — movement plane's holder census.
pub const MOVEMENT_HOLDERS: u16 = 130;
/// `MoveQueue.heap` — movement-task priority heap.
pub const MOVEMENT_HEAP: u16 = 134;
/// `ShuffleCoalescer.shards[i]` — per-destination builder shard (all
/// shards share the rank: they must never nest).
pub const EXCHANGE_SHARD: u16 = 150;
/// `Router.pending` — frames parked for not-yet-registered channels.
pub const ROUTER_PENDING: u16 = 208;
/// `Router.control` — control-plane frame queue (estimates, plans).
pub const ROUTER_CONTROL: u16 = 210;
/// `Outbox.q` — outbound frame queue.
pub const OUTBOX_Q: u16 = 220;
/// `Outbox.credits` — per-destination credit windows (locked after
/// `q` when both are held).
pub const OUTBOX_CREDITS: u16 = 230;
/// `Outbox.send_latency` — per-destination send-latency EWMA.
pub const OUTBOX_SEND_LATENCY: u16 = 236;
/// `Inbox.q` (tcp back-end) — per-worker received-frame queue.
pub const INBOX_TCP_Q: u16 = 250;
/// `Inbox.q` (inproc back-end) — per-worker received-frame queue.
pub const INBOX_INPROC_Q: u16 = 252;
/// `reservation::Inner.reserved` — governor's reserved-byte ledger.
pub const GOVERNOR_RESERVED: u16 = 300;
/// `PressureEvent.state` — pressure epoch + pending reasons. A leaf:
/// raised while `pinned.free`, `sched.listeners`, or an exchange shard
/// is held, and never held across another acquisition itself.
pub const PRESSURE_STATE: u16 = 390;
/// `FaultInjector.state` — the installed fault plan + per-site op
/// counters.
/// Near-leaf: taken briefly inside `fault::check` (which can run under
/// almost any lock in the crate); only the metrics sinks rank above.
pub const FAULT_STATE: u16 = 950;
