//! Serving layer: a two-level cache between the [`Gateway`] and the
//! cluster (tesseract-style result serving — repeated dashboards and
//! drill-downs should not re-run the cluster).
//!
//! # Levels
//!
//! 1. **Exact result cache** — keyed on the canonical plan encoding
//!    ([`key::CanonicalKey::of_plan`] over the canonicalized query's
//!    [`PhysicalPlan::encode`] bytes). A warm hit returns the gathered
//!    [`RecordBatch`] with **zero cluster tasks executed**.
//! 2. **Fragment cache** — materialized scan→filter→agg frontiers
//!    (see [`crate::planner::Logical::fragment_frontiers`]) keyed on
//!    (canonical subplan fingerprint, datasource versions). A plan that
//!    misses the result cache but covers a cached fragment is rewritten
//!    to read the fragment ([`crate::exec::plan::OpSpec::Fragment`])
//!    instead of re-scanning — a pre-aggregated cube serving its
//!    drill-downs (sort/limit/re-aggregation above the frontier still
//!    run, the scan pipeline does not).
//!
//! # Key canonicalization rules
//!
//! See [`key`] module docs: conjunct order always normalizes; column
//! order (scan/project/agg lists) normalizes only below a
//! name-addressed operator (Project/Aggregate); commutative join inputs
//! normalize only under an Aggregate, which absorbs the row and column
//! order a swap perturbs. The gateway executes the canonical form, so
//! cached bytes are byte-identical to what a miss would produce.
//!
//! # Invalidation contract
//!
//! Every entry stores the [`SourceVersion`] stamps of the tables it was
//! computed from, snapshotted *before* execution. Writers bump a
//! table's stamp on [`crate::storage::ObjectStore::put`]; a lookup
//! whose stamps mismatch drops the entry and reports a miss — bumps
//! monotonically grow, so a stale entry can never be re-validated.
//!
//! # Governor accounting
//!
//! Both levels account entry bytes (the batch's encoded length) in one
//! gateway-side [`MemoryGovernor`] [`Reservation`]. Inserts `grow` the
//! reservation; a refused grow **evicts LRU entries until the insert
//! fits** (or is skipped if it can never fit) — it never wedges the
//! query path. Evictions `shrink` it. Budget exhaustion therefore
//! degrades to re-execution, not to blocking.
//!
//! [`Gateway`]: crate::cluster::Gateway
//! [`PhysicalPlan::encode`]: crate::exec::PhysicalPlan::encode

pub mod key;

pub use key::{canonicalize, fingerprint, hash_bytes, CanonicalKey};

use std::sync::Arc;

use crate::sync::{ranks, OrderedMutex};

use crate::memory::{DeviceArena, MemoryGovernor, Reservation};
use crate::metrics::Metrics;
use crate::planner::Logical;
use crate::storage::SourceVersion;
use crate::types::RecordBatch;
use crate::exec::PhysicalPlan;

/// Version stamps an entry was computed against.
pub type VersionSnapshot = Vec<(String, u64)>;

struct Entry<T> {
    key: CanonicalKey,
    value: T,
    bytes: usize,
    versions: VersionSnapshot,
    /// LRU clock stamp (larger = more recently used).
    seq: u64,
}

/// One governor-accounted LRU level. Entries live in a flat vec — the
/// serving cache holds at most a few hundred results, linear scans are
/// noise next to hashing a plan.
struct Lru<T> {
    entries: Vec<Entry<T>>,
    budget: usize,
    bytes: usize,
    clock: u64,
    res: Reservation,
}

/// What an insert attempt did (metrics + tests).
#[derive(Debug, PartialEq, Eq)]
enum InsertOutcome {
    Inserted { evicted: usize },
    TooLarge,
}

impl<T: Clone> Lru<T> {
    fn new(budget: usize, res: Reservation) -> Self {
        Lru { entries: Vec::new(), budget, bytes: 0, clock: 0, res }
    }

    /// Find by full key bytes; validate versions against `current`;
    /// drop-and-miss on mismatch. Returns (value, invalidated-count).
    fn lookup(
        &mut self,
        key: &CanonicalKey,
        current: &VersionSnapshot,
    ) -> (Option<T>, usize) {
        let Some(i) = self.entries.iter().position(|e| e.key == *key) else {
            return (None, 0);
        };
        if self.entries[i].versions != *current {
            self.remove_at(i);
            return (None, 1);
        }
        self.clock += 1;
        self.entries[i].seq = self.clock;
        (Some(self.entries[i].value.clone()), 0)
    }

    fn remove_at(&mut self, i: usize) -> usize {
        let e = self.entries.swap_remove(i);
        self.bytes -= e.bytes;
        self.res.shrink(e.bytes);
        e.bytes
    }

    /// Evict the least-recently-used entry; returns freed bytes.
    fn evict_lru(&mut self) -> Option<usize> {
        let i = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.seq)?
            .0;
        Some(self.remove_at(i))
    }

    /// Insert under the byte budget *and* the governor: evict LRU
    /// entries while either refuses, never block. An entry larger than
    /// the whole budget is skipped outright.
    fn insert(
        &mut self,
        key: CanonicalKey,
        value: T,
        bytes: usize,
        versions: VersionSnapshot,
    ) -> InsertOutcome {
        if bytes > self.budget {
            return InsertOutcome::TooLarge;
        }
        if let Some(i) = self.entries.iter().position(|e| e.key == key) {
            // refreshed fill (e.g. after invalidation): replace
            self.remove_at(i);
        }
        let mut evicted = 0;
        while self.bytes + bytes > self.budget {
            match self.evict_lru() {
                Some(_) => evicted += 1,
                None => break,
            }
        }
        // the governor may be tighter than our budget (it is shared
        // with the sibling level): a refused grow evicts more
        while self.res.grow(bytes).is_err() {
            match self.evict_lru() {
                Some(_) => evicted += 1,
                None => return InsertOutcome::TooLarge,
            }
        }
        self.clock += 1;
        self.entries.push(Entry { key, value, bytes, versions, seq: self.clock });
        self.bytes += bytes;
        InsertOutcome::Inserted { evicted }
    }

    fn invalidate_table(&mut self, table: &str) -> usize {
        let mut dropped = 0;
        let mut i = 0;
        while i < self.entries.len() {
            if self.entries[i].versions.iter().any(|(t, _)| t == table) {
                self.remove_at(i);
                dropped += 1;
            } else {
                i += 1;
            }
        }
        dropped
    }
}

/// Compile-memo entry: canonical fingerprint (+ planner settings) →
/// planned physical plan. Plans are tiny; the memo is entry-capped, not
/// governor-accounted.
struct PlanMemo {
    entries: Vec<(CanonicalKey, Arc<PhysicalPlan>)>,
    cap: usize,
}

impl PlanMemo {
    fn get(&self, key: &CanonicalKey) -> Option<Arc<PhysicalPlan>> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, p)| p.clone())
    }

    fn put(&mut self, key: CanonicalKey, plan: Arc<PhysicalPlan>) {
        if self.entries.len() >= self.cap {
            // wholesale reset — simpler than LRU for a bounded memo of
            // cheap-to-recompute values
            self.entries.clear();
        }
        self.entries.push((key, plan));
    }
}

/// The gateway-side serving cache (results + fragments + plan memo).
pub struct ServingCache {
    results: OrderedMutex<Lru<RecordBatch>>,
    fragments: OrderedMutex<Lru<Arc<Vec<u8>>>>,
    plans: OrderedMutex<PlanMemo>,
    version: Option<SourceVersion>,
    metrics: Arc<Metrics>,
    fragment_budget: usize,
}

impl ServingCache {
    /// Build from the two byte budgets (each 0 = that level off; the
    /// constructor is only called when at least one is nonzero) and the
    /// store's version clock (None = entries never invalidate).
    pub fn new(
        result_bytes: usize,
        fragment_bytes: usize,
        version: Option<SourceVersion>,
    ) -> ServingCache {
        let gov = MemoryGovernor::new(DeviceArena::new(result_bytes + fragment_bytes));
        let r = gov.try_reserve(0).expect("zero-size reservation");
        let f = gov.try_reserve(0).expect("zero-size reservation");
        ServingCache {
            results: OrderedMutex::new(
                ranks::CACHE_RESULTS,
                "cache.results",
                Lru::new(result_bytes, r),
            ),
            fragments: OrderedMutex::new(
                ranks::CACHE_FRAGMENTS,
                "cache.fragments",
                Lru::new(fragment_bytes, f),
            ),
            plans: OrderedMutex::new(
                ranks::CACHE_PLANS,
                "cache.plans",
                PlanMemo { entries: Vec::new(), cap: 256 },
            ),
            version,
            metrics: Arc::new(Metrics::default()),
            fragment_budget: fragment_bytes,
        }
    }

    pub fn fragments_enabled(&self) -> bool {
        self.fragment_budget > 0
    }

    /// `cache.*` counters/gauges (hits, misses, evictions, bytes).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Current version stamps for `tables` (empty when untracked).
    pub fn version_snapshot(&self, tables: &[String]) -> VersionSnapshot {
        match &self.version {
            Some(v) => v.snapshot(tables),
            None => Vec::new(),
        }
    }

    // ------------------------------------------------- result level

    pub fn lookup_result(
        &self,
        key: &CanonicalKey,
        versions: &VersionSnapshot,
    ) -> Option<RecordBatch> {
        let mut lru = self.results.lock();
        let (hit, dropped) = lru.lookup(key, versions);
        self.note("cache.result", hit.is_some(), dropped, lru.bytes);
        hit
    }

    pub fn insert_result(
        &self,
        key: CanonicalKey,
        batch: &RecordBatch,
        versions: VersionSnapshot,
    ) {
        if !self.versions_current(&versions) {
            self.metrics.counter("cache.stale_insert_dropped").inc();
            return;
        }
        let bytes = batch.encoded_len();
        let mut lru = self.results.lock();
        let out = lru.insert(key, batch.clone(), bytes, versions);
        self.note_insert("cache.result", out, lru.bytes);
    }

    // ----------------------------------------------- fragment level

    pub fn lookup_fragment(
        &self,
        key: &CanonicalKey,
        versions: &VersionSnapshot,
    ) -> Option<Arc<Vec<u8>>> {
        let mut lru = self.fragments.lock();
        let (hit, dropped) = lru.lookup(key, versions);
        self.note("cache.fragment", hit.is_some(), dropped, lru.bytes);
        hit
    }

    /// Cache a materialized fragment; returns the encoded bytes for
    /// immediate substitution into the requesting plan.
    pub fn insert_fragment(
        &self,
        key: CanonicalKey,
        batch: &RecordBatch,
        versions: VersionSnapshot,
    ) -> Arc<Vec<u8>> {
        let data = Arc::new(batch.encode());
        if !self.versions_current(&versions) {
            // still hand the bytes back for the requesting query's own
            // substitution (read skew within one query matches the
            // execution that produced it) — just never persist them
            self.metrics.counter("cache.stale_insert_dropped").inc();
            return data;
        }
        let bytes = data.len();
        let mut lru = self.fragments.lock();
        let out = lru.insert(key, data.clone(), bytes, versions);
        self.note_insert("cache.fragment", out, lru.bytes);
        data
    }

    /// Is the pre-execution snapshot still the current clock? A writer
    /// that `put` between the gateway's snapshot and this insert makes
    /// the executed bytes stale *at insert time*: the seed cached them
    /// anyway, stamped with the old versions, and lookups under the
    /// old snapshot then served pre-put data as if it were current.
    /// Version stamps monotonically grow, so equality is sufficient.
    fn versions_current(&self, versions: &VersionSnapshot) -> bool {
        match &self.version {
            Some(v) => versions.iter().all(|(t, stamp)| v.of(t) == *stamp),
            None => true,
        }
    }

    // ----------------------------------------------------- plan memo

    /// Memoized Logical→PhysicalPlan compile, keyed on the canonical
    /// fingerprint plus the planner settings that shape the plan.
    pub fn plan_for(
        &self,
        planner: &crate::planner::Planner,
        canon: &Logical,
    ) -> crate::Result<Arc<PhysicalPlan>> {
        let mut fp = fingerprint(canon);
        fp.extend_from_slice(&(planner.num_workers as u64).to_le_bytes());
        fp.push(planner.lip_enabled as u8);
        let key = CanonicalKey::from_bytes(fp);
        if let Some(p) = self.plans.lock().get(&key) {
            self.metrics.counter("cache.plan_memo_hit").inc();
            return Ok(p);
        }
        self.metrics.counter("cache.plan_memo_miss").inc();
        let plan = Arc::new(planner.plan(canon)?);
        self.plans.lock().put(key, plan.clone());
        Ok(plan)
    }

    /// Drop every entry derived from `table` (explicit invalidation;
    /// the version stamps already catch staleness lazily on lookup).
    pub fn invalidate_table(&self, table: &str) {
        let mut n = 0;
        {
            let mut lru = self.results.lock();
            n += lru.invalidate_table(table);
            self.metrics.gauge("cache.result_bytes").set(lru.bytes as i64);
        }
        {
            let mut lru = self.fragments.lock();
            n += lru.invalidate_table(table);
            self.metrics.gauge("cache.fragment_bytes").set(lru.bytes as i64);
        }
        self.metrics.counter("cache.invalidated").add(n as u64);
    }

    fn note(&self, prefix: &'static str, hit: bool, invalidated: usize, bytes: usize) {
        match (prefix, hit) {
            ("cache.result", true) => self.metrics.counter("cache.result_hit").inc(),
            ("cache.result", false) => self.metrics.counter("cache.result_miss").inc(),
            ("cache.fragment", true) => self.metrics.counter("cache.fragment_hit").inc(),
            (_, false) => self.metrics.counter("cache.fragment_miss").inc(),
            _ => {}
        }
        if invalidated > 0 {
            self.metrics.counter("cache.invalidated").add(invalidated as u64);
        }
        let gauge = if prefix == "cache.result" {
            "cache.result_bytes"
        } else {
            "cache.fragment_bytes"
        };
        self.metrics.gauge(gauge).set(bytes as i64);
    }

    fn note_insert(&self, prefix: &'static str, out: InsertOutcome, bytes: usize) {
        let (evict, refused, gauge) = if prefix == "cache.result" {
            ("cache.result_evict", "cache.result_refused", "cache.result_bytes")
        } else {
            ("cache.fragment_evict", "cache.fragment_refused", "cache.fragment_bytes")
        };
        match out {
            InsertOutcome::Inserted { evicted } if evicted > 0 => {
                self.metrics.counter(evict).add(evicted as u64)
            }
            InsertOutcome::Inserted { .. } => {}
            InsertOutcome::TooLarge => self.metrics.counter(refused).inc(),
        }
        self.metrics.gauge(gauge).set(bytes as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Column;

    fn batch(n: i64) -> RecordBatch {
        RecordBatch::new(vec![Column::i64("k", (0..n).collect())]).unwrap()
    }

    fn key(tag: u8) -> CanonicalKey {
        CanonicalKey::from_bytes(vec![tag; 8])
    }

    #[test]
    fn result_roundtrip_and_lru_eviction_under_budget() {
        let b = batch(64);
        let sz = b.encoded_len();
        // room for exactly two entries
        let cache = ServingCache::new(2 * sz + 1, 0, None);
        cache.insert_result(key(1), &b, Vec::new());
        cache.insert_result(key(2), &b, Vec::new());
        assert!(cache.lookup_result(&key(1), &Vec::new()).is_some());
        // k1 is now MRU; inserting k3 must evict k2
        cache.insert_result(key(3), &b, Vec::new());
        assert!(cache.lookup_result(&key(2), &Vec::new()).is_none());
        assert!(cache.lookup_result(&key(1), &Vec::new()).is_some());
        assert!(cache.lookup_result(&key(3), &Vec::new()).is_some());
        let m = cache.metrics();
        assert_eq!(m.counter_value("cache.result_evict"), 1);
        assert!(m.gauge_value("cache.result_bytes") <= 2 * sz as i64 + 1);
        // cached bytes are byte-identical to what was inserted
        let got = cache.lookup_result(&key(1), &Vec::new()).unwrap();
        assert_eq!(got.encode(), b.encode());
    }

    #[test]
    fn oversized_entry_is_refused_not_wedged() {
        let b = batch(512);
        let cache = ServingCache::new(1024, 0, None); // entry > whole budget
        assert!(b.encoded_len() > 1024);
        cache.insert_result(key(1), &b, Vec::new());
        assert!(cache.lookup_result(&key(1), &Vec::new()).is_none());
        assert_eq!(cache.metrics().counter_value("cache.result_refused"), 1);
        assert_eq!(cache.metrics().gauge_value("cache.result_bytes"), 0);
    }

    #[test]
    fn version_mismatch_invalidates_on_lookup() {
        let b = batch(8);
        let cache = ServingCache::new(1 << 20, 0, None);
        let filled = vec![("t".to_string(), 3u64)];
        cache.insert_result(key(1), &b, filled.clone());
        assert!(cache.lookup_result(&key(1), &filled).is_some());
        let bumped = vec![("t".to_string(), 4u64)];
        assert!(cache.lookup_result(&key(1), &bumped).is_none());
        assert_eq!(cache.metrics().counter_value("cache.invalidated"), 1);
        // entry is gone even for the original stamps
        assert!(cache.lookup_result(&key(1), &filled).is_none());
    }

    #[test]
    fn explicit_table_invalidation_drops_dependents_only() {
        let b = batch(8);
        let cache = ServingCache::new(1 << 20, 1 << 20, None);
        cache.insert_result(key(1), &b, vec![("a".into(), 1)]);
        cache.insert_result(key(2), &b, vec![("b".into(), 1)]);
        cache.insert_fragment(key(3), &b, vec![("a".into(), 1), ("b".into(), 1)]);
        cache.invalidate_table("a");
        assert!(cache.lookup_result(&key(1), &vec![("a".into(), 1)]).is_none());
        assert!(cache.lookup_result(&key(2), &vec![("b".into(), 1)]).is_some());
        assert!(
            cache
                .lookup_fragment(&key(3), &vec![("a".into(), 1), ("b".into(), 1)])
                .is_none(),
            "fragment touching table a must go too"
        );
        assert_eq!(cache.metrics().counter_value("cache.invalidated"), 2);
    }

    #[test]
    fn shared_governor_refusal_evicts_the_inserting_level() {
        let b = batch(64);
        let sz = b.encoded_len();
        // per-level budgets sum to the governor capacity; fill results
        // to its budget, then fragments up to theirs — every insert
        // must land without wedging
        let cache = ServingCache::new(2 * sz, 2 * sz, None);
        cache.insert_result(key(1), &b, Vec::new());
        cache.insert_result(key(2), &b, Vec::new());
        cache.insert_fragment(key(3), &b, Vec::new());
        cache.insert_fragment(key(4), &b, Vec::new());
        // both levels full; next fragment insert evicts a fragment
        cache.insert_fragment(key(5), &b, Vec::new());
        assert!(cache.lookup_fragment(&key(3), &Vec::new()).is_none());
        assert!(cache.lookup_result(&key(1), &Vec::new()).is_some());
        assert!(
            cache.metrics().counter_value("cache.fragment_evict") >= 1,
            "refused grow must evict, not wedge"
        );
    }

    #[test]
    fn fragment_insert_returns_encoded_bytes() {
        let b = batch(16);
        let cache = ServingCache::new(0, 1 << 20, None);
        let data = cache.insert_fragment(key(1), &b, Vec::new());
        assert_eq!(*data, b.encode());
        let hit = cache.lookup_fragment(&key(1), &Vec::new()).unwrap();
        assert_eq!(*hit, b.encode());
        assert!(cache.fragments_enabled());
        assert!(!ServingCache::new(1 << 20, 0, None).fragments_enabled());
    }

    #[test]
    fn stale_insert_is_dropped_when_version_advances_mid_query() {
        let b = batch(8);
        let clock = crate::storage::SourceVersion::new();
        clock.bump("t");
        let cache = ServingCache::new(1 << 20, 1 << 20, Some(clock.clone()));
        // gateway snapshots before execution...
        let snap = cache.version_snapshot(&["t".to_string()]);
        // ...a writer puts mid-execution (version advances)...
        clock.bump("t");
        // ...post-execution insert must drop, not poison the cache
        cache.insert_result(key(1), &b, snap.clone());
        assert!(cache.lookup_result(&key(1), &snap).is_none());
        let fresh = cache.version_snapshot(&["t".to_string()]);
        assert!(cache.lookup_result(&key(1), &fresh).is_none());
        // fragment path: bytes still returned for immediate use
        let data = cache.insert_fragment(key(2), &b, snap);
        assert_eq!(*data, b.encode());
        assert!(cache.lookup_fragment(&key(2), &fresh).is_none());
        assert_eq!(cache.metrics().counter_value("cache.stale_insert_dropped"), 2);
        // a current-snapshot insert still lands
        cache.insert_result(key(3), &b, fresh.clone());
        assert!(cache.lookup_result(&key(3), &fresh).is_some());
    }

    #[test]
    fn plan_memo_hits_and_respects_settings() {
        use crate::exec::plan::{AggFn, AggSpec};
        let cache = ServingCache::new(1 << 20, 0, None);
        let planner = crate::planner::Planner::new(2);
        let q = canonicalize(
            &Logical::scan("t", &["a", "b"])
                .aggregate("a", vec![AggSpec::new(AggFn::Sum, "b")]),
        );
        let p1 = cache.plan_for(&planner, &q).unwrap();
        let p2 = cache.plan_for(&planner, &q).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "second compile memoized");
        assert_eq!(cache.metrics().counter_value("cache.plan_memo_hit"), 1);
        // different worker count → different key → fresh plan
        let planner4 = crate::planner::Planner::new(4);
        let p3 = cache.plan_for(&planner4, &q).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3));
        assert_eq!(p1.encode(), p2.encode());
    }
}
