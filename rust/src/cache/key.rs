//! Canonical plan keys: equivalent [`Logical`] trees map to one key.
//!
//! # Canonicalization rules
//!
//! Rewrites are gated by what downstream operators can *see* of a
//! node's output, tracked top-down as a [`Vis`] flag:
//!
//! * **Conjunct ordering** — `And` trees flatten to leaves, sort by
//!   their encoding, and rebuild left-deep. Always applied: a filter
//!   mask is the intersection of its conjuncts regardless of order, so
//!   neither row content nor row order can change.
//! * **Column ordering** (scan cols, project cols, agg list) — sorted
//!   only when no ancestor exposes column order ([`Vis::ColsAndRows`]):
//!   Project and Aggregate re-pick columns *by name*, so everything
//!   below them absorbs column order; the root and plain
//!   Filter/Sort/Limit chains expose it.
//! * **Commutative join inputs** — the side with the smaller canonical
//!   encoding becomes the build side, only under [`Vis::Nothing`]
//!   (an Aggregate ancestor): a hash aggregate's output is a function
//!   of its input *multiset*, so both the column order and the row
//!   order a swap perturbs are absorbed. (Float sums accumulate in
//!   arrival order; for integer-valued data — every generated workload
//!   here — f64 accumulation is exact, so absorption is byte-precise.)
//!
//! The executed plan *is* the canonical form (the gateway canonicalizes
//! before planning), so a cache hit returns bytes produced by exactly
//! the plan a miss would run — byte-identity by construction, not by
//! cross-plan agreement.
//!
//! The result-cache key hashes the canonical plan's
//! [`PhysicalPlan::encode`] bytes; the plan-memo and fragment keys hash
//! a structural [`fingerprint`] of the canonical `Logical` (available
//! before planning). Full key bytes are stored and compared on lookup —
//! the hash only buckets, collisions cannot alias entries.

use crate::exec::plan::{AggFn, AggSpec, Pred};
use crate::exec::PhysicalPlan;
use crate::planner::Logical;
use crate::util::bytes::Writer;
use crate::util::hash::splitmix64;

/// A collision-safe cache key: `hash` buckets, `bytes` decides.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CanonicalKey {
    pub hash: u64,
    pub bytes: Vec<u8>,
}

impl CanonicalKey {
    pub fn from_bytes(bytes: Vec<u8>) -> CanonicalKey {
        CanonicalKey { hash: hash_bytes(&bytes), bytes }
    }

    /// Result-cache key: the canonical plan's wire encoding.
    pub fn of_plan(plan: &PhysicalPlan) -> CanonicalKey {
        Self::from_bytes(plan.encode())
    }

    /// Fragment / plan-memo key: the canonical logical fingerprint.
    pub fn of_logical(q: &Logical) -> CanonicalKey {
        Self::from_bytes(fingerprint(q))
    }
}

/// SplitMix64-chained hash over arbitrary bytes.
pub fn hash_bytes(b: &[u8]) -> u64 {
    let mut h = 0xC0FF_EE00_D15E_A5E5u64;
    for chunk in b.chunks(8) {
        let mut word = [0u8; 8];
        word[..chunk.len()].copy_from_slice(chunk);
        h = splitmix64(h ^ u64::from_le_bytes(word));
    }
    splitmix64(h ^ b.len() as u64)
}

/// What of a node's output order the ancestors can observe.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Vis {
    /// Column order and row order both reach the result.
    ColsAndRows,
    /// An ancestor re-picks columns by name; row order still reaches.
    RowsOnly,
    /// An Aggregate ancestor absorbs the whole multiset.
    Nothing,
}

impl Vis {
    fn cols_visible(self) -> bool {
        self == Vis::ColsAndRows
    }
}

/// Normalize `q` so that every query in its equivalence class maps to
/// the same tree (see module docs for the rules and their soundness).
pub fn canonicalize(q: &Logical) -> Logical {
    canon(q, Vis::ColsAndRows)
}

fn canon(q: &Logical, vis: Vis) -> Logical {
    match q {
        Logical::Scan { table, cols, pred } => {
            let mut cols = cols.clone();
            if !vis.cols_visible() {
                cols.sort_unstable();
            }
            Logical::Scan {
                table: table.clone(),
                cols,
                pred: pred.as_ref().map(canon_pred),
            }
        }
        Logical::Filter { input, pred } => Logical::Filter {
            input: Box::new(canon(input, vis)),
            pred: canon_pred(pred),
        },
        Logical::Project { input, cols } => {
            let mut cols = cols.clone();
            if !vis.cols_visible() {
                cols.sort_unstable();
            }
            let child = if vis == Vis::Nothing { Vis::Nothing } else { Vis::RowsOnly };
            Logical::Project { input: Box::new(canon(input, child)), cols }
        }
        Logical::Aggregate { input, group_by, aggs } => {
            let mut aggs = aggs.clone();
            if !vis.cols_visible() {
                aggs.sort_by_cached_key(agg_sort_key);
            }
            Logical::Aggregate {
                input: Box::new(canon(input, Vis::Nothing)),
                group_by: group_by.clone(),
                aggs,
            }
        }
        Logical::Join { left, right, left_on, right_on, lip } => {
            let l = canon(left, vis);
            let r = canon(right, vis);
            if vis == Vis::Nothing && fingerprint(&r) < fingerprint(&l) {
                Logical::Join {
                    left: Box::new(r),
                    right: Box::new(l),
                    left_on: right_on.clone(),
                    right_on: left_on.clone(),
                    lip: *lip,
                }
            } else {
                Logical::Join {
                    left: Box::new(l),
                    right: Box::new(r),
                    left_on: left_on.clone(),
                    right_on: right_on.clone(),
                    lip: *lip,
                }
            }
        }
        Logical::Sort { input, by, desc } => Logical::Sort {
            input: Box::new(canon(input, vis)),
            by: by.clone(),
            desc: *desc,
        },
        Logical::Limit { input, n } => {
            Logical::Limit { input: Box::new(canon(input, vis)), n: *n }
        }
        Logical::Fragment { data } => Logical::Fragment { data: data.clone() },
    }
}

/// Flatten the conjunction, sort leaves by encoding, rebuild left-deep.
fn canon_pred(p: &Pred) -> Pred {
    let mut leaves: Vec<Pred> = p.conjuncts().into_iter().cloned().collect();
    leaves.sort_by_cached_key(|l| {
        let mut w = Writer::new();
        enc_pred(l, &mut w);
        w.finish()
    });
    leaves
        .into_iter()
        .reduce(|acc, x| acc.and(x))
        .expect("conjuncts() is non-empty")
}

fn agg_sort_key(a: &AggSpec) -> Vec<u8> {
    let mut w = Writer::new();
    enc_agg(a, &mut w);
    w.finish()
}

// ------------------------------------------- structural fingerprints

/// Deterministic structural encoding of a `Logical` tree. Injective for
/// our plan algebra (tagged, length-prefixed), so byte equality is tree
/// equality.
pub fn fingerprint(q: &Logical) -> Vec<u8> {
    let mut w = Writer::new();
    enc_logical(q, &mut w);
    w.finish()
}

fn enc_logical(q: &Logical, w: &mut Writer) {
    match q {
        Logical::Scan { table, cols, pred } => {
            w.u8(0);
            w.str(table);
            w.u32(cols.len() as u32);
            for c in cols {
                w.str(c);
            }
            match pred {
                None => w.u8(0),
                Some(p) => {
                    w.u8(1);
                    enc_pred(p, w);
                }
            }
        }
        Logical::Filter { input, pred } => {
            w.u8(1);
            enc_pred(pred, w);
            enc_logical(input, w);
        }
        Logical::Project { input, cols } => {
            w.u8(2);
            w.u32(cols.len() as u32);
            for c in cols {
                w.str(c);
            }
            enc_logical(input, w);
        }
        Logical::Aggregate { input, group_by, aggs } => {
            w.u8(3);
            w.str(group_by);
            w.u32(aggs.len() as u32);
            for a in aggs {
                enc_agg(a, w);
            }
            enc_logical(input, w);
        }
        Logical::Join { left, right, left_on, right_on, lip } => {
            w.u8(4);
            w.str(left_on);
            w.str(right_on);
            w.u8(*lip as u8);
            enc_logical(left, w);
            enc_logical(right, w);
        }
        Logical::Sort { input, by, desc } => {
            w.u8(5);
            w.str(by);
            w.u8(*desc as u8);
            enc_logical(input, w);
        }
        Logical::Limit { input, n } => {
            w.u8(6);
            w.u64(*n);
            enc_logical(input, w);
        }
        Logical::Fragment { data } => {
            w.u8(7);
            w.bytes(data);
        }
    }
}

fn enc_pred(p: &Pred, w: &mut Writer) {
    match p {
        Pred::RangeI64 { col, lo, hi } => {
            w.u8(0);
            w.str(col);
            w.i64(*lo);
            w.i64(*hi);
        }
        Pred::RangeF32 { col, lo, hi } => {
            w.u8(1);
            w.str(col);
            w.u32(lo.to_bits());
            w.u32(hi.to_bits());
        }
        Pred::EqI64 { col, val } => {
            w.u8(2);
            w.str(col);
            w.i64(*val);
        }
        Pred::And(a, b) => {
            w.u8(3);
            enc_pred(a, w);
            enc_pred(b, w);
        }
    }
}

fn enc_agg(a: &AggSpec, w: &mut Writer) {
    w.u8(match a.func {
        AggFn::Sum => 0,
        AggFn::Count => 1,
        AggFn::Min => 2,
        AggFn::Max => 3,
    });
    w.str(&a.col);
    w.str(&a.name);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred_a() -> Pred {
        Pred::RangeI64 { col: "a".into(), lo: 0, hi: 10 }
    }

    fn pred_b() -> Pred {
        Pred::EqI64 { col: "b".into(), val: 3 }
    }

    #[test]
    fn conjunct_order_is_normalized_everywhere() {
        let q1 = Logical::scan("t", &["a", "b"]).filter(pred_a().and(pred_b()));
        let q2 = Logical::scan("t", &["a", "b"]).filter(pred_b().and(pred_a()));
        assert_eq!(fingerprint(&canonicalize(&q1)), fingerprint(&canonicalize(&q2)));
        // and in pushed-down scan predicates
        let s1 = Logical::scan_where("t", &["a"], pred_a().and(pred_b()));
        let s2 = Logical::scan_where("t", &["a"], pred_b().and(pred_a()));
        assert_eq!(fingerprint(&canonicalize(&s1)), fingerprint(&canonicalize(&s2)));
    }

    #[test]
    fn visible_column_order_is_preserved() {
        // no aggregate/project above: scan col order IS the result order
        let q1 = Logical::scan("t", &["a", "b"]);
        let q2 = Logical::scan("t", &["b", "a"]);
        assert_ne!(fingerprint(&canonicalize(&q1)), fingerprint(&canonicalize(&q2)));
    }

    #[test]
    fn absorbed_column_order_is_normalized() {
        use crate::exec::plan::{AggFn, AggSpec};
        let agg = |q: Logical| q.aggregate("a", vec![AggSpec::new(AggFn::Sum, "b")]);
        let q1 = agg(Logical::scan("t", &["a", "b"]));
        let q2 = agg(Logical::scan("t", &["b", "a"]));
        assert_eq!(fingerprint(&canonicalize(&q1)), fingerprint(&canonicalize(&q2)));
    }

    #[test]
    fn join_inputs_commute_only_under_aggregate() {
        use crate::exec::plan::{AggFn, AggSpec};
        let l = || Logical::scan("t", &["k", "v"]);
        let r = || Logical::scan("u", &["k2", "w"]);
        let j1 = l().join(r(), "k", "k2", false);
        let j2 = r().join(l(), "k2", "k", false);
        // visible join: orientation is part of the result
        assert_ne!(fingerprint(&canonicalize(&j1)), fingerprint(&canonicalize(&j2)));
        // under an aggregate: both orientations collapse
        let a1 = j1.aggregate("k", vec![AggSpec::new(AggFn::Sum, "w")]);
        let a2 = j2.aggregate("k", vec![AggSpec::new(AggFn::Sum, "w")]);
        assert_eq!(fingerprint(&canonicalize(&a1)), fingerprint(&canonicalize(&a2)));
    }

    #[test]
    fn canonicalize_is_idempotent() {
        let q = Logical::scan("t", &["b", "a"])
            .filter(pred_b().and(pred_a()))
            .aggregate(
                "a",
                vec![
                    crate::exec::plan::AggSpec::new(crate::exec::plan::AggFn::Sum, "b"),
                ],
            )
            .sort("a", false);
        let once = canonicalize(&q);
        let twice = canonicalize(&once);
        assert_eq!(fingerprint(&once), fingerprint(&twice));
    }

    #[test]
    fn distinct_constants_get_distinct_keys() {
        let q1 = Logical::scan("t", &["a"])
            .filter(Pred::RangeI64 { col: "a".into(), lo: 0, hi: 10 });
        let q2 = Logical::scan("t", &["a"])
            .filter(Pred::RangeI64 { col: "a".into(), lo: 0, hi: 11 });
        assert_ne!(
            CanonicalKey::of_logical(&canonicalize(&q1)),
            CanonicalKey::of_logical(&canonicalize(&q2))
        );
    }

    #[test]
    fn hash_bytes_is_stable_and_length_sensitive() {
        assert_eq!(hash_bytes(b"abc"), hash_bytes(b"abc"));
        assert_ne!(hash_bytes(b"abc"), hash_bytes(b"abcd"));
        assert_ne!(hash_bytes(b""), hash_bytes(b"\0"));
    }
}
