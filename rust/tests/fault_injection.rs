//! Deterministic fault-injection suite (see FAULTS.md).
//!
//! These tests install process-global [`theseus::fault`] plans, so they
//! live in their own test binary: an installed plan can never leak
//! faults into unrelated lib or integration tests running in other
//! processes. *Within* this binary the tests serialize on `SERIAL` —
//! fault-free baselines must run with no plan installed, and the
//! injector's per-site op counters are process-wide, so two tests
//! interleaving would corrupt each other's schedules.
//!
//! Every test snapshots its metrics into
//! `target/fault_injection_metrics.txt` *before* asserting, so a CI
//! failure uploads the schedule that actually ran.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use theseus::cluster::client::{connect, Client};
use theseus::config::WorkerConfig;
use theseus::exec::plan::{AggFn, AggSpec, Pred};
use theseus::fault::{self, FaultPlan, FaultSite};
use theseus::memory::spill::SpillStore;
use theseus::metrics::Metrics;
use theseus::planner::Logical;
use theseus::sim::SimContext;
use theseus::storage::compression::Codec;
use theseus::storage::format::FileWriter;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::types::{Column, DType, Field, RecordBatch, Schema};
use theseus::util::rng::Rng;

/// Serializes the whole suite: baselines need a fault-free process and
/// the injector's op counters are global. (The install guard alone is
/// not enough — it only covers the scope's lifetime, not the fault-free
/// phases around it.)
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// Write the per-test metrics artifact before any assertion can panic.
fn artifact(test: &str, detail: &str, metrics: &Metrics) {
    let _ = std::fs::create_dir_all("target");
    let _ = std::fs::write(
        "target/fault_injection_metrics.txt",
        format!("test: {test}\n{detail}\n\n{}", metrics.snapshot()),
    );
}

// ---------------------------------------------------------- injector

/// Explicit nth-op rules fire exactly on schedule, firings are mirrored
/// into the installed metrics sink, and dropping the scope restores the
/// no-op fast path.
#[test]
fn nth_schedule_fires_exactly_and_scope_restores() {
    let _g = serial();
    let m = Arc::new(Metrics::default());
    let plan = FaultPlan::new()
        .fail_nth(FaultSite::StorageGet, 2)
        .fail_nth_count(FaultSite::SpillRead, 1, 2);
    let scope = fault::install_with_metrics(plan, Some(m.clone()));
    let total0 = fault::injected_total();
    let get0 = fault::injected_for(FaultSite::StorageGet);

    assert!(fault::check(FaultSite::StorageGet).is_ok(), "op 1: before nth");
    let err = fault::check(FaultSite::StorageGet).unwrap_err();
    assert!(err.is_transient(), "injected faults must classify transient");
    assert!(err.is_retryable());
    assert!(err.to_string().contains("storage_get"), "site named in error: {err}");
    assert!(fault::check(FaultSite::StorageGet).is_ok(), "op 3: past nth");

    assert!(fault::check(FaultSite::SpillRead).is_err(), "count window op 1");
    assert!(fault::check(FaultSite::SpillRead).is_err(), "count window op 2");
    assert!(fault::check(FaultSite::SpillRead).is_ok(), "count window closed");
    // an unscheduled site never fires
    assert!(fault::check(FaultSite::NetRecv).is_ok());

    artifact("nth_schedule", "explicit rules: storage_get@2, spill_read@1..2", &m);
    assert_eq!(fault::injected_total() - total0, 3);
    assert_eq!(fault::injected_for(FaultSite::StorageGet) - get0, 1);
    assert_eq!(m.counter_value("fault.injected_total"), 3);
    assert_eq!(m.counter_value("fault.injected_total.storage_get"), 1);
    assert_eq!(m.counter_value("fault.injected_total.spill_read"), 2);

    drop(scope);
    let after = fault::injected_total();
    for site in FaultSite::ALL {
        assert!(fault::check(site).is_ok(), "uninstalled injector must pass");
    }
    assert_eq!(fault::injected_total(), after, "no counting once uninstalled");
}

/// The seeded mode is a pure function of (seed, op sequence): two
/// installs of the same plan fire on exactly the same ops.
#[test]
fn seeded_plans_replay_identically() {
    let _g = serial();
    let run = || {
        let _scope = fault::install(FaultPlan::seeded(0xFEED_FACE, 400, 8));
        (0..64)
            .map(|_| fault::check(FaultSite::StorageGet).is_err())
            .collect::<Vec<bool>>()
    };
    let a = run();
    let b = run();
    artifact(
        "seeded_replay",
        &format!("firings: {}", a.iter().filter(|f| **f).count()),
        &Metrics::default(),
    );
    assert_eq!(a, b, "same seed + same workload must fire on the same ops");
    let fired = a.iter().filter(|f| **f).count();
    assert!(fired > 0, "per-mille 400 over 64 ops must fire at least once");
    assert!(fired <= 8, "max_faults must cap the seeded mode");
}

// ------------------------------------------------------------- spill

/// An injected segment-write fault fails over into a fresh segment: the
/// old one is sealed poisoned, the payload lands byte-identically, and
/// the failover is counted. A sustained write storm (more faults than
/// the failover ladder tolerates) surfaces as a transient error instead
/// of looping forever.
#[test]
fn spill_write_failover_rotates_and_preserves_bytes() {
    let _g = serial();
    let store = SpillStore::temp("fault-failover").unwrap();
    let m = Arc::new(Metrics::default());

    let scope = fault::install_with_metrics(
        FaultPlan::new()
            .fail_nth(FaultSite::SpillWrite, 1)
            .fail_nth(FaultSite::SpillRead, 2),
        Some(m.clone()),
    );
    let slot = store.write_vectored(&[b"hello ", b"spilled ", b"world"]).unwrap();
    artifact(
        "spill_failover",
        &format!("failovers: {}", store.write_failover_total()),
        &m,
    );
    assert_eq!(store.write_failover_total(), 1, "one fault, one failover");
    assert_eq!(store.read(slot).unwrap(), b"hello spilled world");
    // spill_read op 2 is scheduled: the second read fails transient,
    // the third sees the same bytes again — reads are idempotent
    let err = store.read(slot).unwrap_err();
    assert!(err.is_transient(), "injected spill read: {err}");
    assert_eq!(store.read(slot).unwrap(), b"hello spilled world");
    drop(scope);

    // a storm longer than the failover ladder (> 3 rotations) must
    // give up loudly rather than rotate segments forever
    let _scope = fault::install(FaultPlan::new().fail_nth_count(FaultSite::SpillWrite, 1, 16));
    let err = store.write_vectored(&[b"doomed"]).unwrap_err();
    assert!(err.is_transient(), "exhausted failover stays transient: {err}");
    assert!(store.write_failover_total() > 1, "storm must have rotated segments");
}

// ----------------------------------------------------------- cluster

const SEED: u64 = 42;

/// Integer-valued fact table (f64 sums of small integers are exact and
/// order-independent, so results compare byte-for-byte).
fn write_facts(store: &dyn ObjectStore, files: usize, rows: usize) {
    let mut rng = Rng::new(SEED);
    let schema =
        Schema::new(vec![Field::new("k", DType::Int64), Field::new("v", DType::Int64)]);
    for f in 0..files {
        let batch = RecordBatch::new(vec![
            Column::i64("k", (0..rows).map(|_| rng.gen_i64(0, 9)).collect()),
            Column::i64("v", (0..rows).map(|_| rng.gen_i64(0, 99)).collect()),
        ])
        .unwrap();
        let mut w = FileWriter::new(schema.clone(), Codec::Zstd { level: 1 }, 256);
        w.write(batch).unwrap();
        store.put(&format!("facts/{f}.ths"), &w.finish().unwrap()).unwrap();
    }
}

fn facts_query() -> Logical {
    Logical::scan("facts", &["k", "v"])
        .filter(Pred::RangeI64 { col: "k".into(), lo: 0, hi: 10 })
        .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
        .sort("k", false)
}

fn facts_client(cfg: WorkerConfig) -> (Client, Arc<SimObjectStore>) {
    let store = SimObjectStore::in_memory(&SimContext::test());
    write_facts(&*store, 4, 600);
    let client = connect(cfg, store.clone(), None).unwrap();
    (client, store)
}

/// The acceptance schedule: one deterministic plan covering a transient
/// object-store read fault (absorbed by the storage retry ladder), a
/// spill-segment write fault (absorbed by failover), and a dropped
/// first network send (absorbed by the lane's send-retry) — and the
/// query result stays byte-identical to the fault-free baseline.
#[test]
fn three_plane_schedule_recovers_byte_identically() {
    let _g = serial();
    let (client, _store) = facts_client(WorkerConfig {
        num_workers: 2,
        storage_backoff_base_ms: 0,
        ..WorkerConfig::test()
    });
    let q = facts_query();
    let baseline = client.query(&q).unwrap();

    let metrics = client.gateway().cluster.metrics.clone();
    let spill = SpillStore::temp("fault-three-plane").unwrap();
    let scope = fault::install_with_metrics(
        FaultPlan::new()
            // ops 2 and 3 of storage_get fail: whatever call sites they
            // land on see at most 2 consecutive failures, within the
            // default storage_retry_limit of 3
            .fail_nth_count(FaultSite::StorageGet, 2, 2)
            // the very first frame send fails once; the sender lane
            // retries it in place (4 attempts before peer-down)
            .fail_nth(FaultSite::NetSend, 1)
            // the first spill-segment write fails; failover rotates
            .fail_nth(FaultSite::SpillWrite, 1),
        Some(metrics.clone()),
    );

    // spill plane: same installed schedule, exercised directly
    let slot = spill.write_vectored(&[b"three-plane"]).unwrap();
    assert_eq!(spill.read(slot).unwrap(), b"three-plane");

    // storage + network planes: the full cluster query under faults
    let faulted = client.query(&q).unwrap();

    artifact(
        "three_plane",
        &format!(
            "injected: {} (storage_get {}, net_send {}, spill_write {})",
            metrics.counter_value("fault.injected_total"),
            metrics.counter_value("fault.injected_total.storage_get"),
            metrics.counter_value("fault.injected_total.net_send"),
            metrics.counter_value("fault.injected_total.spill_write"),
        ),
        &metrics,
    );
    assert_eq!(
        faulted.batch.encode(),
        baseline.batch.encode(),
        "recovered run must be byte-identical to the fault-free baseline"
    );
    assert_eq!(metrics.counter_value("fault.injected_total.spill_write"), 1);
    assert_eq!(metrics.counter_value("fault.injected_total.storage_get"), 2);
    assert_eq!(metrics.counter_value("fault.injected_total.net_send"), 1);
    assert!(
        metrics.counter_value("retry.attempts_total") > 0,
        "recovery must have gone through the bounded-retry ladder"
    );
    assert_eq!(
        metrics.counter_value("gateway.query_retry_total"),
        0,
        "op-level ladders must absorb this schedule before the gateway rung"
    );

    drop(scope);
    let clean = client.query(&q).unwrap();
    assert_eq!(clean.batch.encode(), baseline.batch.encode());
}

/// A storage-fault window longer than the op-level retry ladder
/// escalates to the gateway rung: the whole query is torn down and
/// re-run (fresh qid, fresh per-query state) until the schedule is
/// exhausted, and the final result is still byte-identical.
#[test]
fn storage_exhaustion_escalates_to_query_retry() {
    let _g = serial();
    let (client, _store) = facts_client(WorkerConfig {
        num_workers: 2,
        storage_retry_limit: 2,
        storage_backoff_base_ms: 0,
        query_retry_limit: 6,
        ..WorkerConfig::test()
    });
    let q = facts_query();
    let baseline = client.query(&q).unwrap();

    let metrics = client.gateway().cluster.metrics.clone();
    // 8 consecutive storage failures: every op-level ladder (limit 2)
    // exhausts, each failed run burns >= 2 ops, so the gateway recovers
    // within at most 4 re-runs — inside query_retry_limit = 6
    let scope = fault::install_with_metrics(
        FaultPlan::new().fail_nth_count(FaultSite::StorageGet, 1, 8),
        Some(metrics.clone()),
    );
    let faulted = client.query(&q).unwrap();
    drop(scope);

    artifact(
        "query_retry",
        &format!(
            "query re-runs: {}",
            metrics.counter_value("gateway.query_retry_total")
        ),
        &metrics,
    );
    assert_eq!(faulted.batch.encode(), baseline.batch.encode());
    assert!(
        metrics.counter_value("gateway.query_retry_total") >= 1,
        "an exhausted storage ladder must escalate to a query re-run"
    );
    assert!(metrics.counter_value("retry.attempts_total") > 0);
    assert_eq!(
        client.gateway().admission.reserved_bytes(),
        0,
        "admission grant returned after the retried query"
    );
}

/// A schedule that outlasts `query_retry_limit` fails *cleanly*: the
/// caller gets a retryable error, no admission reservation leaks, and
/// the next query (fault scope dropped) succeeds byte-identically.
#[test]
fn retry_exhaustion_is_clean_and_leak_free() {
    let _g = serial();
    let (client, _store) = facts_client(WorkerConfig {
        num_workers: 2,
        storage_retry_limit: 2,
        storage_backoff_base_ms: 0,
        query_retry_limit: 1,
        ..WorkerConfig::test()
    });
    let q = facts_query();
    let baseline = client.query(&q).unwrap();

    let metrics = client.gateway().cluster.metrics.clone();
    // an effectively-permanent storage storm: every attempt of every
    // run fails, so op-level retry, then the single allowed re-run,
    // then the gateway give up in order
    let scope = fault::install_with_metrics(
        FaultPlan::new().fail_nth_count(FaultSite::StorageGet, 1, 100_000),
        Some(metrics.clone()),
    );
    let err = client.query(&q).unwrap_err();
    drop(scope);

    artifact("retry_exhausted", &format!("error: {err}"), &metrics);
    assert!(err.is_transient(), "exhaustion must stay transient: {err}");
    assert!(err.is_retryable(), "callers may resubmit: {err}");
    assert!(
        metrics.counter_value("gateway.query_retry_total") >= 1,
        "the re-run budget must have been spent before giving up"
    );
    assert!(
        metrics.counter_value("retry.exhausted_total") >= 1,
        "giving up must be counted"
    );
    assert_eq!(
        client.gateway().admission.reserved_bytes(),
        0,
        "failed query must not leak its admission reservation"
    );
    // the cluster is still healthy: same client, next query succeeds
    let after = client.query(&q).unwrap();
    assert_eq!(after.batch.encode(), baseline.batch.encode());
}

// ----------------------------------------------------------- network

/// Injected mid-frame disconnect (satellite of FAULTS.md §network): a
/// send-fault storm longer than the lane's attempt budget drops the
/// frame with peer-down escalation; the dropped frame's credit never
/// comes back, so the rest of the data stays credit-blocked — and
/// [`Outbox::close`] must discard those frames loudly
/// (`net.close_unsent_total`), let the Finish drain, and leave
/// [`NetworkExecutor::flush`] returning instead of hanging.
#[test]
fn outbox_close_discards_blocked_frames_after_peer_down() {
    use theseus::config::TransportKind;
    use theseus::exec::WorkerCtx;
    use theseus::executors::network::{ChannelRx, NetworkExecutor, Outbox, Router};
    use theseus::memory::BatchHolder;
    use theseus::network::InprocHub;

    let _g = serial();
    let ctx = WorkerCtx::test();
    let hub = InprocHub::new(1, &SimContext::test(), TransportKind::Tcp);
    let ep = hub.endpoints().remove(0);
    let metrics = Arc::new(Metrics::default());
    let router = Arc::new(Router::new());
    router.install_metrics(metrics.clone());
    let outbox = Arc::new(Outbox::new(64));
    outbox.enable_credits(1);
    outbox.install_metrics(metrics.clone());

    // the first frame's send dies on all 4 lane attempts
    // (NET_SEND_ATTEMPTS) -> peer-down, frame dropped
    let scope = fault::install_with_metrics(
        FaultPlan::new().fail_nth_count(FaultSite::NetSend, 1, 4),
        Some(metrics.clone()),
    );

    let net = NetworkExecutor::start(Arc::new(ep), outbox.clone(), router.clone(), None, None, 1);
    let rx_holder = BatchHolder::new("rx", ctx.env.clone());
    let rx = Arc::new(ChannelRx::new(rx_holder.clone(), 1));
    router.register(9, rx.clone());

    for i in 0..3i64 {
        let b = RecordBatch::new(vec![Column::i64("k", vec![i; 8])]).unwrap();
        outbox.send_encoded(0, 9, b.encode()).unwrap();
    }
    outbox.send_finish(0, 9).unwrap();

    // frame 1 consumes the only credit, then dies mid-send
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while metrics.counter_value("net.peer_down_total") < 1 {
        assert!(std::time::Instant::now() < deadline, "peer-down never escalated");
        std::thread::sleep(Duration::from_millis(2));
    }

    // frames 2 and 3 are credit-blocked forever (their credit died with
    // frame 1); close must discard them and surface the Finish
    outbox.close();
    assert!(net.flush(Duration::from_secs(10)), "flush must not hang after close");
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while !rx_holder.is_finished() {
        assert!(std::time::Instant::now() < deadline, "Finish never drained");
        std::thread::sleep(Duration::from_millis(2));
    }
    drop(scope);

    artifact(
        "outbox_close",
        &format!(
            "close_unsent: {} peer_down: {} send_retry: {}",
            outbox.close_unsent(),
            metrics.counter_value("net.peer_down_total"),
            metrics.counter_value("net.send_retry_total"),
        ),
        &metrics,
    );
    let stats = rx_holder.stats();
    assert_eq!(
        stats.device_batches + stats.host_batches + stats.disk_batches,
        0,
        "the dropped frame must not have been delivered"
    );
    assert_eq!(outbox.close_unsent(), 2, "both blocked data frames discarded");
    assert_eq!(metrics.counter_value("net.close_unsent_total"), 2);
    assert_eq!(metrics.counter_value("net.peer_down_total"), 1);
    assert_eq!(
        metrics.counter_value("net.send_retry_total"),
        3,
        "attempts 2..4 of the doomed frame count as retries"
    );
    assert_eq!(metrics.counter_value("fault.injected_total.net_send"), 4);
    assert!(outbox.is_empty(), "drain completed");
    net.stop();
}

// ---------------------------------------------------------- property

/// One random schedule entry. Site 3 (net_send) is generated with
/// `count <= 2` — below the lane's 4-attempt budget — so a frame is
/// never dropped outright; `net_recv` is excluded entirely (a dropped
/// frame wedges the exchange until the query deadline, which is a
/// liveness scenario, not a recovery one).
#[derive(Clone, Debug)]
struct SchedRule {
    site: u8,
    nth: u64,
    count: u64,
}

impl theseus::testing::Shrink for SchedRule {
    fn shrink(&self) -> Vec<SchedRule> {
        let mut out = Vec::new();
        if self.count > 1 {
            out.push(SchedRule { count: self.count / 2, ..*self });
        }
        if self.nth > 1 {
            out.push(SchedRule { nth: self.nth / 2, ..*self });
        }
        out
    }
}

fn sched_site(tag: u8) -> FaultSite {
    match tag % 4 {
        0 => FaultSite::StorageGet,
        1 => FaultSite::SpillRead,
        2 => FaultSite::SpillWrite,
        _ => FaultSite::NetSend,
    }
}

fn gen_sched(rng: &mut Rng) -> Vec<SchedRule> {
    let n = 1 + rng.gen_range(3) as usize;
    (0..n)
        .map(|_| {
            let site = rng.gen_range(4) as u8;
            let count = if site % 4 == 3 {
                1 + rng.gen_range(2)
            } else {
                1 + rng.gen_range(5)
            };
            SchedRule { site, nth: 1 + rng.gen_range(10), count }
        })
        .collect()
}

/// Every generated schedule must land in one of exactly two end states:
/// the recovery ladders absorb it and the result is byte-identical to
/// the fault-free baseline, or the gateway gives up with a *retryable*
/// error. Either way no admission reservation may leak.
#[test]
fn random_schedules_recover_or_fail_retryably() {
    let _g = serial();
    let (client, _store) = facts_client(WorkerConfig {
        num_workers: 2,
        storage_retry_limit: 2,
        storage_backoff_base_ms: 0,
        query_retry_limit: 3,
        query_timeout_ms: 15_000,
        ..WorkerConfig::test()
    });
    let q = facts_query();
    let baseline = client.query(&q).unwrap().batch.encode();
    let metrics = client.gateway().cluster.metrics.clone();

    theseus::testing::check(0x5C4ED, 6, gen_sched, |rules: &Vec<SchedRule>| {
        let mut plan = FaultPlan::new();
        for r in rules {
            plan = plan.fail_nth_count(sched_site(r.site), r.nth, r.count);
        }
        let scope = fault::install_with_metrics(plan, Some(metrics.clone()));
        let res = client.query(&q);
        drop(scope);
        artifact("random_schedules", &format!("rules: {rules:?}"), &metrics);
        let outcome_ok = match res {
            Ok(r) => r.batch.encode() == baseline,
            Err(e) => e.is_retryable(),
        };
        outcome_ok && client.gateway().admission.reserved_bytes() == 0
    });
}
