//! Cross-module integration tests: the full engine against the CPU
//! baseline, spilling under pressure, transport equivalence, failure
//! handling, and end-to-end property checks.

use std::sync::Arc;

use theseus::cluster::client::connect;
use theseus::cluster::{Cluster, Gateway};
use theseus::config::{TransportKind, WorkerConfig};
use theseus::planner::Logical;
use theseus::runtime::KernelRegistry;
use theseus::sim::SimContext;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::types::{ColumnData, RecordBatch};
use theseus::workload::tpcds::TpcdsGen;
use theseus::workload::{tpcds_lite_suite, tpch_suite, CpuEngine, TpchGen};

fn tpch_store(sf: f64) -> Arc<SimObjectStore> {
    let store = SimObjectStore::in_memory(&SimContext::test());
    let mut g = TpchGen::new(sf);
    g.row_group_rows = 1024;
    g.rows_per_file = 4096;
    let dynstore: Arc<dyn ObjectStore> = store.clone();
    g.write_all(&dynstore).unwrap();
    store
}

/// Multiset column comparison (sorted per column, f64 tolerance for the
/// device's f32 partial sums; ties across engines order differently).
fn assert_batches_match(id: &str, a: &RecordBatch, b: &RecordBatch) {
    assert_eq!(a.rows(), b.rows(), "{id}: row count");
    assert_eq!(a.num_columns(), b.num_columns(), "{id}: column count");
    for (ca, cb) in a.columns.iter().zip(&b.columns) {
        assert_eq!(ca.name, cb.name, "{id}: column names");
        match (&ca.data, &cb.data) {
            (ColumnData::I64(x), ColumnData::I64(y)) => {
                let mut x = x.clone();
                let mut y = y.clone();
                x.sort_unstable();
                y.sort_unstable();
                assert_eq!(x, y, "{id}: column {}", ca.name);
            }
            (ColumnData::F64(x), ColumnData::F64(y)) => {
                let mut x = x.clone();
                let mut y = y.clone();
                x.sort_by(|p, q| p.partial_cmp(q).unwrap());
                y.sort_by(|p, q| p.partial_cmp(q).unwrap());
                for (u, v) in x.iter().zip(&y) {
                    assert!(
                        (u - v).abs() <= 2e-3 * v.abs().max(1.0),
                        "{id}: {} {u} vs {v}",
                        ca.name
                    );
                }
            }
            _ => panic!("{id}: unexpected column layouts"),
        }
    }
}

/// The flagship integration test: every suite query produces the same
/// result from the 3-worker distributed engine (AOT kernels when built)
/// and the single-threaded CPU baseline.
#[test]
fn distributed_engine_matches_cpu_baseline_tpch() {
    let store = tpch_store(0.001);
    let registry = KernelRegistry::shared().ok();
    let client = connect(
        WorkerConfig { num_workers: 3, ..WorkerConfig::test() },
        store.clone(),
        registry,
    )
    .unwrap();
    let baseline = CpuEngine::new(store);
    for q in tpch_suite() {
        let r = client.query(&q.logical()).unwrap_or_else(|e| panic!("{}: {e}", q.id));
        let b = baseline.run(&q.logical()).unwrap();
        assert_batches_match(q.id, &r.batch, &b.batch);
    }
}

#[test]
fn distributed_engine_matches_cpu_baseline_tpcds() {
    let store = SimObjectStore::in_memory(&SimContext::test());
    let mut g = TpcdsGen::new(0.002);
    g.row_group_rows = 1024;
    let dynstore: Arc<dyn ObjectStore> = store.clone();
    g.write_all(&dynstore).unwrap();
    let client = connect(
        WorkerConfig { num_workers: 2, ..WorkerConfig::test() },
        store.clone(),
        KernelRegistry::shared().ok(),
    )
    .unwrap();
    let baseline = CpuEngine::new(store);
    for q in tpcds_lite_suite() {
        let r = client.query(&q.logical()).unwrap_or_else(|e| panic!("{}: {e}", q.id));
        let b = baseline.run(&q.logical()).unwrap();
        assert_batches_match(q.id, &r.batch, &b.batch);
    }
}

/// Results stay exact when the device is far too small and everything
/// spills (the Fig-5 SF=100k-on-2-nodes property).
#[test]
fn correctness_under_forced_spilling() {
    let store = tpch_store(0.002);
    let cfg = WorkerConfig {
        num_workers: 2,
        device_capacity: 40 << 10, // ~2 x 16 KiB scan batches
        spill_watermark: 0.5,
        ..WorkerConfig::test()
    };
    let client = connect(cfg, store.clone(), None).unwrap();
    let baseline = CpuEngine::new(store);
    let q = tpch_suite().into_iter().find(|q| q.id == "q18").unwrap();
    let r = client.query(&q.logical()).unwrap();
    let b = baseline.run(&q.logical()).unwrap();
    assert_batches_match("q18-spill", &r.batch, &b.batch);
    assert!(
        r.total_spills() > 0,
        "expected spills with a 192 KiB device (got {:?})",
        r.worker_stats.iter().map(|s| s.spills).collect::<Vec<_>>()
    );
}

/// The real-TCP transport produces the same results as in-proc.
#[test]
fn tcp_and_inproc_transports_agree() {
    let q = tpch_suite().into_iter().find(|q| q.id == "q12").unwrap();
    let mut results = Vec::new();
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let store = tpch_store(0.001);
        let cfg = WorkerConfig { num_workers: 2, transport, ..WorkerConfig::test() };
        let client = connect(cfg, store, None).unwrap();
        results.push(client.query(&q.logical()).unwrap().batch);
    }
    assert_batches_match("q12-transport", &results[0], &results[1]);
}

/// Planner errors (bad column) surface as clean failures and leave the
/// cluster reusable.
#[test]
fn failed_query_does_not_poison_the_cluster() {
    let store = tpch_store(0.001);
    let cluster = Cluster::launch(
        WorkerConfig { num_workers: 2, ..WorkerConfig::test() },
        store,
        None,
    )
    .unwrap();
    let gw = Gateway::new(cluster);

    let bad = Logical::scan("lineitem", &["no_such_column"]);
    assert!(gw.submit(&bad).is_err());

    let good = tpch_suite().into_iter().find(|q| q.id == "q6").unwrap();
    let r = gw.submit(&good.logical()).unwrap();
    assert!(r.batch.rows() > 0, "cluster unusable after failed query");
}

/// Sequential suite runs on one cluster leave no residue (§4 runs
/// queries sequentially; holders/channels must be fully recycled).
#[test]
fn repeated_suite_runs_are_stable() {
    let store = tpch_store(0.001);
    let client = connect(
        WorkerConfig { num_workers: 2, ..WorkerConfig::test() },
        store,
        None,
    )
    .unwrap();
    let q = tpch_suite().into_iter().find(|q| q.id == "q3").unwrap();
    let first = client.query(&q.logical()).unwrap().batch;
    for _ in 0..3 {
        let again = client.query(&q.logical()).unwrap().batch;
        assert_batches_match("q3-repeat", &first, &again);
    }
}

/// Property: exchange + aggregation conserves row counts for any key
/// distribution (uniform, skewed, constant).
#[test]
fn aggregation_conserves_counts_property() {
    use theseus::exec::plan::{AggFn, AggSpec};
    for (name, skew) in [("uniform", 0.0), ("zipf", 0.8)] {
        let store = SimObjectStore::in_memory(&SimContext::test());
        let mut g = TpchGen::new(0.001);
        g.skew = skew;
        g.row_group_rows = 1024;
        let dynstore: Arc<dyn ObjectStore> = store.clone();
        g.write_all(&dynstore).unwrap();
        let client = connect(
            WorkerConfig { num_workers: 3, ..WorkerConfig::test() },
            store,
            None,
        )
        .unwrap();
        let q = Logical::scan("lineitem", &["l_orderkey", "l_quantity"])
            .aggregate("l_orderkey", vec![AggSpec::new(AggFn::Count, "l_quantity")]);
        let r = client.query(&q).unwrap();
        let counts = r.batch.column("count_l_quantity").unwrap().data.as_f64().unwrap();
        let total: f64 = counts.iter().sum();
        assert_eq!(total as usize, g.lineitem_rows(), "{name}: rows lost in exchange");
    }
}
