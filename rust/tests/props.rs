//! Property tests (via `theseus::testing`'s check + Shrink harness) for
//! the PR-2 data-plane surface:
//!
//! * `SlabWriter` / `SlabSlice`: random write/split/adopt sequences
//!   preserve byte content and never leak pool pages (`in_use` returns
//!   to 0), including forced heap-fallback under a scarce pool.
//! * Frame wire round-trip: a random `Payload` (heap and slab-backed,
//!   control and data, compressed and not) survives `encode_header` +
//!   vectored write → the receive-path `read_frame` decode, including
//!   the pool-dry heap-fallback branch.
//! * Slab-native codecs (PR 4): random corpora split at random chunk
//!   boundaries → `compress_chunks_into` a slab → vectored wire →
//!   `decompress_slices_into` a slab → byte-identical, for all three
//!   codecs, with matches spanning chunk boundaries and the pool-dry
//!   heap fallback.
//! * Coalesced shuffle (PR 5): random tables × random batch splits ×
//!   random worker counts × random flush thresholds × pool-dry staging
//!   — the destination-coalesced scatter path delivers, per
//!   destination, rows byte-identical to the seed's per-batch
//!   `take`-and-send routing.
//! * Credit-based backpressure (PR 6): random Data/Finish/Grant/Pop
//!   interleavings against a credit-gated `Outbox` — no data frame is
//!   ever popped beyond granted credit, per-destination FIFO holds
//!   (a Finish never overtakes blocked data), and after `close` every
//!   Finish still drains while discarded blocked data is surfaced on
//!   `close_unsent`.

use theseus::exec::operators::{kernels, ShuffleCoalescer};
use theseus::exec::WorkerCtx;
use theseus::executors::network::{stage_encoded, Outbound, Outbox};
use theseus::memory::batch_holder::MemEnv;
use theseus::memory::{BatchHolder, PinnedPool, PinnedSlab, SlabSlice, SlabWriter, StagedBytes};
use theseus::metrics::Metrics;
use theseus::network::frame::{DEFAULT_MAX_FRAME_BYTES, FRAME_HEADER_LEN};
use theseus::network::{read_frame, Frame, FrameKind, Payload};
use theseus::storage::compression::Codec;
use theseus::testing::{check, gen, Shrink};
use theseus::types::{Column, RecordBatch};
use theseus::util::hash;
use theseus::util::rng::Rng;
use theseus::Error;

// ---------------------------------------------------------------- slabs

/// One step of a slab lifecycle.
#[derive(Clone, Debug)]
enum SlabOp {
    /// Append bytes through the writer.
    Write(Vec<u8>),
    /// Sub-slice the finished slab at (offset, len) — raw values,
    /// reduced modulo the slab length at use.
    Slice(usize, usize),
    /// Adopt the slab into a Batch Holder and pop it back out.
    Adopt,
}

impl Shrink for SlabOp {
    fn shrink(&self) -> Vec<SlabOp> {
        match self {
            SlabOp::Write(v) => v.shrink().into_iter().map(SlabOp::Write).collect(),
            SlabOp::Slice(a, b) => {
                let mut out = Vec::new();
                for (x, y) in [(0, *b), (a / 2, *b), (*a, b / 2), (*a, 0)] {
                    if (x, y) != (*a, *b) {
                        out.push(SlabOp::Slice(x, y));
                    }
                }
                out
            }
            SlabOp::Adopt => Vec::new(),
        }
    }
}

#[derive(Clone, Debug)]
struct SlabCase {
    /// Pre-hold most of the pool so writes hit the exhaustion path.
    scarce: bool,
    ops: Vec<SlabOp>,
}

impl Shrink for SlabCase {
    fn shrink(&self) -> Vec<SlabCase> {
        let mut out: Vec<SlabCase> = self
            .ops
            .shrink()
            .into_iter()
            .map(|ops| SlabCase { scarce: self.scarce, ops })
            .collect();
        if self.scarce {
            out.push(SlabCase { scarce: false, ops: self.ops.clone() });
        }
        out
    }
}

fn gen_slab_case(rng: &mut Rng) -> SlabCase {
    let n = rng.gen_range(6) as usize + 1;
    let ops = (0..n)
        .map(|_| match rng.gen_range(4) {
            0 | 1 => SlabOp::Write(gen::bytes(rng, 120)),
            2 => SlabOp::Slice(rng.next_u64() as usize, rng.next_u64() as usize),
            _ => SlabOp::Adopt,
        })
        .collect();
    SlabCase { scarce: rng.gen_bool(0.3), ops }
}

/// Run one slab lifecycle; true when every invariant held.
fn slab_case_holds(case: &SlabCase) -> bool {
    // 32-byte buffers force multi-buffer slabs from even small writes
    let pool = PinnedPool::new(32, 8).unwrap();
    let held: Vec<_> = if case.scarce {
        (0..6).map(|_| pool.try_acquire().unwrap()).collect()
    } else {
        Vec::new()
    };

    let mut w = SlabWriter::new(&pool);
    let mut expected: Vec<u8> = Vec::new();
    for op in &case.ops {
        if let SlabOp::Write(data) = op {
            match w.write_bytes(data) {
                // exhaustion keeps the bytes already copied intact:
                // resync the model from the writer's own length
                Ok(()) | Err(Error::PinnedExhausted { .. }) => {
                    let copied = w.len() - expected.len();
                    expected.extend_from_slice(&data[..copied]);
                }
                Err(e) => panic!("unexpected write error: {e}"),
            }
        }
    }
    let slab = w.finish();
    if slab.len() != expected.len() || slab.read() != expected {
        return false;
    }
    let whole = SlabSlice::whole(slab);

    for op in &case.ops {
        match op {
            SlabOp::Write(_) => {}
            SlabOp::Slice(a, b) => {
                let off = a % (expected.len() + 1);
                let len = b % (expected.len() - off + 1);
                let s = whole.slice(off, len);
                let want = &expected[off..off + len];
                let mut via_chunks = Vec::new();
                for c in s.chunks() {
                    via_chunks.extend_from_slice(c);
                }
                if s.to_vec() != want || via_chunks != want || *s.contiguous() != *want {
                    return false;
                }
            }
            SlabOp::Adopt => {
                // `whole` stays alive, so the holder sees a shared view
                // and must re-stage (pinned if the pool has room, heap
                // fallback otherwise) — content survives either way.
                let env = MemEnv::test(1 << 20);
                let holder = BatchHolder::new("adopt", env);
                holder
                    .push_host_bytes(StagedBytes::Pinned(whole.clone()))
                    .unwrap();
                let back = holder.pop_encoded().unwrap().unwrap();
                if *back.contiguous() != expected[..] {
                    return false;
                }
            }
        }
    }

    drop(whole);
    drop(held);
    // never leak pool pages: everything returned, in_use == 0
    pool.free_buffers() == pool.total_buffers()
}

#[test]
fn slab_write_split_adopt_preserves_bytes_and_leaks_nothing() {
    check(0xC0FFEE, 300, gen_slab_case, slab_case_holds);
}

#[test]
fn slab_exclusive_adopt_hands_buffers_over() {
    // The non-shared adopt path: the holder takes the slab's buffers
    // without copying, and popping returns the very same pool bytes.
    check(
        7,
        100,
        |rng| gen::bytes(rng, 200),
        |data| {
            let pool = PinnedPool::new(32, 16).unwrap();
            let env = {
                let mut env = MemEnv::test(1 << 20);
                env.pinned = Some(pool.clone());
                env
            };
            let slab = PinnedSlab::write(&pool, data).unwrap();
            let holder = BatchHolder::new("x", env);
            holder
                .push_host_bytes(StagedBytes::Pinned(SlabSlice::whole(slab)))
                .unwrap();
            let bounced = pool.bounce_bytes();
            let back = holder.pop_encoded().unwrap().unwrap();
            let ok = *back.contiguous() == data[..]
                && pool.bounce_bytes() == bounced; // no re-copy on adopt
            drop(back);
            ok && pool.free_buffers() == pool.total_buffers()
        },
    );
}

// --------------------------------------------------------------- frames

#[derive(Clone, Debug)]
struct FrameCase {
    /// Data frame (pool-eligible) vs Control frame.
    data_kind: bool,
    /// Send side wraps a pinned slab vs plain heap bytes.
    pinned_send: bool,
    /// Payload body is zstd-compressed (receiver decompresses after).
    compressed: bool,
    /// Receive-side pool: 0 = none, 1 = installed but dry, 2 = roomy.
    rx_pool: usize,
    prelude: Vec<u8>,
    body: Vec<u8>,
}

impl Shrink for FrameCase {
    fn shrink(&self) -> Vec<FrameCase> {
        let mut out = Vec::new();
        for body in self.body.shrink() {
            out.push(FrameCase { body, ..self.clone() });
        }
        if !self.prelude.is_empty() {
            out.push(FrameCase { prelude: Vec::new(), ..self.clone() });
        }
        for (field, val) in [
            (self.pinned_send, FrameCase { pinned_send: false, ..self.clone() }),
            (self.compressed, FrameCase { compressed: false, ..self.clone() }),
        ] {
            if field {
                out.push(val);
            }
        }
        if self.rx_pool != 0 {
            out.push(FrameCase { rx_pool: 0, ..self.clone() });
        }
        out
    }
}

fn gen_frame_case(rng: &mut Rng) -> FrameCase {
    FrameCase {
        data_kind: rng.gen_bool(0.7),
        pinned_send: rng.gen_bool(0.5),
        compressed: rng.gen_bool(0.4),
        rx_pool: rng.gen_range(3) as usize,
        prelude: gen::bytes(rng, 8),
        body: gen::bytes(rng, 600),
    }
}

/// One wire round-trip; true when the received frame is exact.
fn frame_case_holds(case: &FrameCase) -> bool {
    let payload_bytes = if case.compressed {
        Codec::Zstd { level: 1 }.compress(&case.body)
    } else {
        case.body.clone()
    };
    let mut expected = case.prelude.clone();
    expected.extend_from_slice(&payload_bytes);

    let kind = if case.data_kind { FrameKind::Data } else { FrameKind::Control };
    // keep the tx pool alive for the slab's lifetime
    let tx_pool = PinnedPool::new(16, 64).unwrap();
    let payload = if case.pinned_send {
        match PinnedSlab::write(&tx_pool, &payload_bytes) {
            Ok(slab) => Payload::pinned(case.prelude.clone(), SlabSlice::whole(slab)),
            // pool too small for this payload: the send path's fallback
            Err(Error::PinnedExhausted { .. }) => Payload::Heap(expected.clone()),
            Err(e) => panic!("{e}"),
        }
    } else {
        Payload::Heap(expected.clone())
    };
    let frame = Frame { kind, src: 3, dst: 1, channel: 77, payload };

    // the exact byte sequence tcp's vectored send produces:
    // len-prefix + header + payload chunks
    let mut wire = Vec::new();
    wire.extend_from_slice(&(frame.wire_len() as u64).to_le_bytes());
    wire.extend_from_slice(&frame.encode_header());
    for c in frame.payload.chunks() {
        wire.extend_from_slice(c);
    }
    // vectored framing must agree with the contiguous encoder
    if wire[8..] != frame.encode_to_vec()[..] {
        return false;
    }

    let pool = PinnedPool::new(32, 64).unwrap();
    let hold_all: Vec<_> = if case.rx_pool == 1 {
        (0..pool.total_buffers()).map(|_| pool.try_acquire().unwrap()).collect()
    } else {
        Vec::new()
    };
    let rx_pool = if case.rx_pool == 0 { None } else { Some(pool.clone()) };

    let total = u64::from_le_bytes(wire[..8].try_into().unwrap()) as usize;
    let mut cur = std::io::Cursor::new(&wire[8..]);
    let got = match read_frame(&mut cur, total, DEFAULT_MAX_FRAME_BYTES, || rx_pool) {
        Ok(f) => f,
        Err(_) => return false,
    };
    // the stream position must land exactly on the frame boundary
    if cur.position() as usize != total {
        return false;
    }
    if (got.kind, got.src, got.dst, got.channel) != (kind, 3, 1, 77) {
        return false;
    }
    if *got.payload.contiguous() != expected[..] {
        return false;
    }
    // pool routing: only Data payloads land pinned, and only when the
    // pool is installed with room; everything else heap-falls-back
    let expect_pinned = case.data_kind && case.rx_pool == 2 && !expected.is_empty();
    if got.payload.is_pinned() != expect_pinned {
        return false;
    }
    // compressed payloads decompress back to the original body
    if case.compressed {
        let raw = got.payload.contiguous();
        match Codec::decompress(&raw[case.prelude.len()..]) {
            Ok(d) if d == case.body => {}
            _ => return false,
        }
    }
    drop(got);
    drop(hold_all);
    if pool.free_buffers() != pool.total_buffers() {
        return false; // receive leaked pool pages
    }
    // header length sanity against the wire constant
    wire.len() == 8 + FRAME_HEADER_LEN + expected.len()
}

#[test]
fn frame_roundtrip_survives_vectored_wire_and_pool_fallback() {
    check(0xF4A3E, 400, gen_frame_case, frame_case_holds);
}

// ---------------------------------------------------------------- codecs

/// One slab-native codec round trip: chunked corpus → compress into a
/// slab → vectored wire → decompress from split chunks into a slab.
#[derive(Clone, Debug)]
struct CodecCase {
    /// 0 = None, 1 = Zstd, 2 = Lz4Like.
    codec_tag: u8,
    /// 0 = random bytes, 1 = byte runs (RLE/overlap matches),
    /// 2 = repeated tile longer than most chunks (matches *must* span
    /// chunk boundaries to be found).
    style: u8,
    /// Corpus length — raw value, reduced modulo the cap at use.
    len: usize,
    seed: u64,
    /// Chunk boundaries — raw values, reduced modulo `len + 1` at use.
    splits: Vec<usize>,
    /// Pre-hold the whole pool on both ends: every stage must take the
    /// heap fallback and still round-trip.
    dry: bool,
}

impl Shrink for CodecCase {
    fn shrink(&self) -> Vec<CodecCase> {
        let mut out: Vec<CodecCase> = self
            .len
            .shrink()
            .into_iter()
            .map(|len| CodecCase { len, ..self.clone() })
            .collect();
        out.extend(
            self.splits
                .shrink()
                .into_iter()
                .map(|splits| CodecCase { splits, ..self.clone() }),
        );
        if self.dry {
            out.push(CodecCase { dry: false, ..self.clone() });
        }
        if self.style != 0 {
            out.push(CodecCase { style: 0, ..self.clone() });
        }
        out
    }
}

fn gen_codec_case(rng: &mut Rng) -> CodecCase {
    let nsplits = rng.gen_range(6) as usize;
    CodecCase {
        codec_tag: rng.gen_range(3) as u8,
        style: rng.gen_range(3) as u8,
        len: rng.gen_range(4000) as usize,
        seed: rng.next_u64(),
        splits: (0..nsplits).map(|_| rng.next_u64() as usize).collect(),
        dry: rng.gen_bool(0.2),
    }
}

fn make_corpus(style: u8, len: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed | 1);
    match style {
        0 => (0..len).map(|_| rng.next_u64() as u8).collect(),
        1 => {
            let mut v = Vec::with_capacity(len);
            while v.len() < len {
                let b = rng.next_u64() as u8;
                let run = (rng.gen_range(40) + 1) as usize;
                v.extend(std::iter::repeat(b).take(run.min(len - v.len())));
            }
            v
        }
        _ => {
            let tile: Vec<u8> = (0..97).map(|_| rng.next_u64() as u8).collect();
            (0..len).map(|i| tile[i % tile.len()]).collect()
        }
    }
}

fn codec_case_holds(case: &CodecCase) -> bool {
    let codec = match case.codec_tag % 3 {
        0 => Codec::None,
        1 => Codec::Zstd { level: 1 },
        _ => Codec::Lz4Like,
    };
    let len = case.len % 4000;
    let data = make_corpus(case.style % 3, len, case.seed);

    // random chunk boundaries (empty chunks are legal slab shapes)
    let mut points: Vec<usize> = case.splits.iter().map(|s| s % (len + 1)).collect();
    points.sort_unstable();
    let mut chunks: Vec<&[u8]> = Vec::new();
    let mut prev = 0usize;
    for &p in &points {
        chunks.push(&data[prev..p]);
        prev = p;
    }
    chunks.push(&data[prev..]);

    // ---- 1. compress the chunks straight into a slab (64-byte pool
    // buffers force multi-buffer output); a dry pool must fall back
    // exactly like the send path does
    let tx_pool = PinnedPool::new(64, 128).unwrap();
    let tx_hold: Vec<_> = if case.dry {
        (0..tx_pool.total_buffers()).map(|_| tx_pool.try_acquire().unwrap()).collect()
    } else {
        Vec::new()
    };
    let mut w = SlabWriter::new(&tx_pool);
    let compressed: Vec<u8> = match codec.compress_chunks_into(&chunks, &mut w) {
        Ok(n) => {
            let slab = w.finish();
            if slab.len() != n {
                return false; // returned size must match bytes written
            }
            slab.read()
        }
        Err(_) => {
            if !case.dry {
                return false; // a roomy pool must never fail
            }
            drop(w);
            codec.compress_chunks(&chunks)
        }
    };
    // the chunk-cursor LZ is pure addressing: byte-identical output to
    // the contiguous compressor, for every split
    if codec == Codec::Lz4Like && compressed != codec.compress(&data) {
        return false;
    }
    drop(tx_hold);
    if tx_pool.free_buffers() != tx_pool.total_buffers() {
        return false; // compression leaked pool pages
    }

    // ---- 2. vectored wire round-trip
    let frame = Frame::data(0, 1, 9, compressed.clone());
    let wire = frame.encode_to_vec();
    let mut cur = std::io::Cursor::new(&wire[..]);
    let back = match read_frame(&mut cur, wire.len(), DEFAULT_MAX_FRAME_BYTES, || None) {
        Ok(f) => f,
        Err(_) => return false,
    };
    let body = back.payload.to_vec();
    if body != compressed {
        return false;
    }

    // ---- 3. decompress from split chunks (receive path reassembles
    // nothing — split at a different boundary than the input, cutting
    // through the prelude) into a slab, or heap when dry
    let mid = body.len() / 3;
    let in_chunks: Vec<&[u8]> = vec![&body[..mid], &body[mid..]];
    let rx_pool = PinnedPool::new(64, 128).unwrap();
    let out: Vec<u8> = if case.dry {
        let hold: Vec<_> =
            (0..rx_pool.total_buffers()).map(|_| rx_pool.try_acquire().unwrap()).collect();
        if SlabWriter::with_capacity(&rx_pool, data.len().max(1)).is_ok() {
            return false; // dry pool must refuse
        }
        drop(hold);
        let mut v = Vec::new();
        match Codec::decompress_slices_into(&in_chunks, &mut v) {
            Ok(orig) if orig == data.len() => v,
            _ => return false,
        }
    } else {
        let mut w = match SlabWriter::with_capacity(&rx_pool, data.len()) {
            Ok(w) => w,
            Err(_) => return false,
        };
        match Codec::decompress_slices_into(&in_chunks, &mut w) {
            Ok(orig) if orig == data.len() && w.len() == orig => w.finish().read(),
            _ => return false,
        }
    };
    out == data && rx_pool.free_buffers() == rx_pool.total_buffers()
}

#[test]
fn codec_chunked_slab_wire_roundtrip_is_byte_identical() {
    check(0xC0DEC, 250, gen_codec_case, codec_case_holds);
}

// -------------------------------------------------------------- shuffle

/// One randomized coalesced-shuffle scenario.
#[derive(Clone, Debug)]
struct ShuffleCase {
    /// Total rows — raw, reduced modulo the cap at use.
    rows: usize,
    /// Batch boundaries — raw, reduced modulo `rows + 1` at use.
    splits: Vec<usize>,
    /// Worker count — raw, reduced to 1..=8 at use.
    workers: usize,
    /// Flush threshold — raw, reduced at use (1 = coalescing off).
    flush: usize,
    seed: u64,
    /// Pre-hold the whole pool: every flush must heap-fall-back and
    /// still deliver identical bytes.
    dry: bool,
}

impl Shrink for ShuffleCase {
    fn shrink(&self) -> Vec<ShuffleCase> {
        let mut out: Vec<ShuffleCase> = self
            .rows
            .shrink()
            .into_iter()
            .map(|rows| ShuffleCase { rows, ..self.clone() })
            .collect();
        out.extend(
            self.splits
                .shrink()
                .into_iter()
                .map(|splits| ShuffleCase { splits, ..self.clone() }),
        );
        if self.dry {
            out.push(ShuffleCase { dry: false, ..self.clone() });
        }
        if self.workers % 8 != 0 {
            out.push(ShuffleCase { workers: 0, ..self.clone() }); // -> 1 worker
        }
        out
    }
}

fn gen_shuffle_case(rng: &mut Rng) -> ShuffleCase {
    let nsplits = rng.gen_range(8) as usize;
    ShuffleCase {
        rows: rng.gen_range(1500) as usize,
        splits: (0..nsplits).map(|_| rng.next_u64() as usize).collect(),
        workers: rng.next_u64() as usize,
        flush: rng.next_u64() as usize,
        seed: rng.next_u64(),
        dry: rng.gen_bool(0.2),
    }
}

fn shuffle_case_holds(case: &ShuffleCase) -> bool {
    const PARTS: u32 = 16;
    let rows = case.rows % 1500;
    let workers = case.workers % 8 + 1;
    // spans 1 (coalescing off) .. ~6 KiB (several batches per flush)
    let flush = case.flush % 6144 + 1;

    let mut rng = Rng::new(case.seed | 1);
    let table = RecordBatch::new(vec![
        Column::i64("k", (0..rows).map(|_| rng.gen_i64(-(1 << 40), 1 << 40)).collect()),
        Column::i64("w", (0..rows).map(|_| rng.gen_i64(0, 1 << 20)).collect()),
    ])
    .unwrap();
    // random batch boundaries (empty batches are legal)
    let mut points: Vec<usize> = case.splits.iter().map(|s| s % (rows + 1)).collect();
    points.sort_unstable();
    let mut batches = Vec::new();
    let mut prev = 0usize;
    for &p in points.iter().chain(std::iter::once(&rows)) {
        batches.push(table.slice(prev, p - prev).unwrap());
        prev = p;
    }

    // ---- seed routing: per-batch per-destination take lists, kept in
    // arrival order per destination
    let mut reference: Vec<Vec<RecordBatch>> = vec![Vec::new(); workers];
    for b in &batches {
        if b.is_empty() {
            continue;
        }
        let keys = b.column("k").unwrap().data.as_i64().unwrap();
        let mut by_dst: Vec<Vec<u32>> = vec![Vec::new(); workers];
        for (row, &k) in keys.iter().enumerate() {
            by_dst[hash::partition_id(k, PARTS) as usize % workers].push(row as u32);
        }
        for (dst, idx) in by_dst.into_iter().enumerate() {
            if !idx.is_empty() {
                reference[dst].push(b.take(&idx).unwrap());
            }
        }
    }

    // ---- coalesced routing: single-pass scatter -> builders -> flush
    // -> slab-native staging -> decode back
    let ctx = WorkerCtx::test();
    let metrics = std::sync::Arc::new(Metrics::default());
    // big enough for the worst single flush (a whole table routed to
    // one destination): staging must only fall back when forced dry
    let pool = PinnedPool::new(1024, 64).unwrap();
    let hold: Vec<_> = if case.dry {
        (0..pool.total_buffers()).map(|_| pool.try_acquire().unwrap()).collect()
    } else {
        Vec::new()
    };
    let co = ShuffleCoalescer::new(workers, flush, None, metrics.clone());
    let mut received: Vec<Vec<RecordBatch>> = vec![Vec::new(); workers];
    let deliver = |dst: usize, batch: &RecordBatch, out: &mut Vec<Vec<RecordBatch>>| {
        // the wire hop: pooled staging (or its dry fallback) + decode
        let staged = stage_encoded(batch, Some(&pool));
        if staged.is_pinned() == case.dry {
            return false; // roomy must pin, dry must fall back
        }
        match RecordBatch::decode(&staged.contiguous()) {
            Ok(b) => {
                out[dst].push(b);
                true
            }
            Err(_) => false,
        }
    };
    for b in &batches {
        if b.is_empty() {
            continue;
        }
        let keys = b.column("k").unwrap().data.as_i64().unwrap();
        let plan = match kernels::partition_scatter(&ctx, keys, PARTS, workers) {
            Ok(p) => p,
            Err(_) => return false,
        };
        for (dst, flushed) in co.append(b, &plan).unwrap() {
            if !deliver(dst, &flushed, &mut received) {
                return false;
            }
        }
    }
    for (dst, flushed) in co.flush_all() {
        if !deliver(dst, &flushed, &mut received) {
            return false;
        }
    }
    drop(hold);

    // ---- identity: per destination, the coalesced rows are
    // byte-identical to the seed routing (order within a destination
    // preserved)
    for dst in 0..workers {
        let want = RecordBatch::concat(&reference[dst]).unwrap();
        let got = RecordBatch::concat(&received[dst]).unwrap();
        if want.encode() != got.encode() {
            return false;
        }
    }
    // accounting: every routed byte went through a counted flush, and
    // dry-pool staging is visible on the gauge
    let routed: u64 = batches.iter().map(|b| b.byte_size() as u64).sum();
    if metrics.counter_value("exchange.coalesced_bytes") != routed {
        return false;
    }
    let frames: usize = received.iter().map(|d| d.len()).sum();
    if metrics.counter_value("exchange.flush_total") != frames as u64 {
        return false;
    }
    if case.dry && frames > 0 && pool.codec_heap_fallback_bytes() == 0 {
        return false;
    }
    pool.free_buffers() == pool.total_buffers()
}

#[test]
fn coalesced_shuffle_matches_seed_routing_byte_for_byte() {
    check(0x5F1E, 250, gen_shuffle_case, shuffle_case_holds);
}

// ------------------------------------------------------- credit gating

/// One step against a credit-gated outbox.
#[derive(Clone, Debug)]
enum CreditOp {
    /// Queue a data frame for `dst` (consumes one credit when popped).
    Data(usize),
    /// Queue end-of-stream for `dst` (credit-exempt, but FIFO-held
    /// behind blocked data).
    Finish(usize),
    /// The receiver returns `amount` credits for `dst`.
    Grant(usize, u64),
    /// A sender lane asks for the next sendable frame.
    Pop,
}

impl Shrink for CreditOp {
    fn shrink(&self) -> Vec<CreditOp> {
        match self {
            CreditOp::Grant(d, a) if *a > 1 => vec![CreditOp::Grant(*d, a / 2)],
            _ => Vec::new(),
        }
    }
}

#[derive(Clone, Debug)]
struct CreditCase {
    window: u64,
    ops: Vec<CreditOp>,
}

impl Shrink for CreditCase {
    fn shrink(&self) -> Vec<CreditCase> {
        let mut out: Vec<CreditCase> = self
            .ops
            .shrink()
            .into_iter()
            .map(|ops| CreditCase { window: self.window, ops })
            .collect();
        if self.window > 1 {
            out.push(CreditCase { window: self.window - 1, ops: self.ops.clone() });
        }
        out
    }
}

fn gen_credit_case(rng: &mut Rng) -> CreditCase {
    const DSTS: u64 = 2;
    let n = rng.gen_range(18) as usize + 4;
    let ops = (0..n)
        .map(|_| match rng.gen_range(8) {
            0..=2 => CreditOp::Data(rng.gen_range(DSTS) as usize),
            3 => CreditOp::Finish(rng.gen_range(DSTS) as usize),
            4 | 5 => CreditOp::Grant(rng.gen_range(DSTS) as usize, rng.gen_range(2) + 1),
            _ => CreditOp::Pop,
        })
        .collect();
    CreditCase { window: rng.gen_range(3) + 1, ops }
}

fn credit_case_holds(case: &CreditCase) -> bool {
    const DSTS: usize = 2;
    let outbox = Outbox::new(64);
    outbox.enable_credits(case.window as usize);
    let metrics = std::sync::Arc::new(Metrics::default());
    outbox.install_metrics(metrics.clone());
    let pop = |ob: &Outbox| ob.pop_for_lane(0, 1, std::time::Duration::ZERO);

    // shadow of the sender's credit state: starts at the window,
    // grants cap at it, each delivered data frame consumes one
    let w = case.window.max(1);
    let mut rem = [w; DSTS];
    // per-destination FIFO model: Some(seq) = data, None = finish
    let mut fifo: Vec<std::collections::VecDeque<Option<u8>>> =
        vec![std::collections::VecDeque::new(); DSTS];
    let mut seq = [0u8; DSTS];
    let (mut pushed_data, mut popped_data) = ([0u64; DSTS], [0u64; DSTS]);
    let (mut pushed_fin, mut popped_fin) = ([0u64; DSTS], [0u64; DSTS]);

    for op in &case.ops {
        match op {
            CreditOp::Data(dst) => {
                outbox.send_encoded(*dst, 7, vec![*dst as u8, seq[*dst]]).unwrap();
                fifo[*dst].push_back(Some(seq[*dst]));
                seq[*dst] = seq[*dst].wrapping_add(1);
                pushed_data[*dst] += 1;
            }
            CreditOp::Finish(dst) => {
                outbox.send_finish(*dst, 7).unwrap();
                fifo[*dst].push_back(None);
                pushed_fin[*dst] += 1;
            }
            CreditOp::Grant(dst, amount) => {
                outbox.grant_credits(*dst, *amount);
                rem[*dst] = (rem[*dst] + amount).min(w);
            }
            CreditOp::Pop => match pop(&outbox) {
                None => {
                    // a None pop is only legal when every queued frame
                    // is FIFO-held behind credit-blocked data
                    for d in 0..DSTS {
                        if !fifo[d].is_empty() && !(fifo[d][0].is_some() && rem[d] == 0) {
                            return false;
                        }
                    }
                }
                Some(Outbound::Data { dst, encoded, .. }) => {
                    if rem[dst] == 0 {
                        return false; // delivered beyond granted credit
                    }
                    rem[dst] -= 1;
                    popped_data[dst] += 1;
                    match fifo[dst].pop_front() {
                        Some(Some(s)) if *encoded.contiguous() == [dst as u8, s] => {}
                        _ => return false, // out of FIFO order
                    }
                }
                Some(Outbound::Finish { dst, .. }) => {
                    popped_fin[dst] += 1;
                    if fifo[dst].pop_front() != Some(None) {
                        return false; // Finish overtook queued data
                    }
                }
                Some(Outbound::Estimate { .. }) => return false,
            },
        }
    }

    // Close must release the lane: sendable frames (and every Finish)
    // still drain; credit-blocked data is discarded and surfaced.
    outbox.close();
    let mut discarded = 0u64;
    loop {
        let Some(m) = pop(&outbox) else { break };
        match m {
            Outbound::Data { dst, encoded, .. } => {
                if rem[dst] == 0 {
                    return false;
                }
                rem[dst] -= 1;
                popped_data[dst] += 1;
                match fifo[dst].pop_front() {
                    Some(Some(s)) if *encoded.contiguous() == [dst as u8, s] => {}
                    _ => return false,
                }
            }
            Outbound::Finish { dst, .. } => {
                // blocked data queued ahead of this Finish was
                // discarded by the closing scan
                while rem[dst] == 0 && fifo[dst].front().is_some_and(|e| e.is_some()) {
                    fifo[dst].pop_front();
                    discarded += 1;
                }
                popped_fin[dst] += 1;
                if fifo[dst].pop_front() != Some(None) {
                    return false;
                }
            }
            Outbound::Estimate { .. } => return false,
        }
    }
    // whatever the model still holds must be exactly the blocked data
    // the close discarded — never an undelivered Finish
    for d in 0..DSTS {
        while rem[d] == 0 && fifo[d].front().is_some_and(|e| e.is_some()) {
            fifo[d].pop_front();
            discarded += 1;
        }
        if !fifo[d].is_empty() {
            return false;
        }
        if popped_fin[d] != pushed_fin[d] {
            return false;
        }
    }
    // every queued data frame was either delivered or loudly discarded
    let pushed: u64 = pushed_data.iter().sum();
    let popped: u64 = popped_data.iter().sum();
    if popped + discarded != pushed {
        return false;
    }
    outbox.close_unsent() == discarded
        && metrics.counter_value("net.close_unsent_total") == discarded
}

#[test]
fn credit_round_trip_never_exceeds_grants_and_always_finishes() {
    check(0xC4ED17, 300, gen_credit_case, credit_case_holds);
}

#[test]
fn truncated_streams_error_instead_of_hanging_or_panicking() {
    // Corollary the reader thread relies on: cutting the wire short at
    // any point yields Err, never a wrong frame.
    check(
        11,
        200,
        |rng| (gen::bytes(rng, 120), rng.next_u64() as usize),
        |(body, cut)| {
            let frame = Frame::data(0, 1, 5, body.clone());
            let wire = frame.encode_to_vec();
            let cut = cut % wire.len().max(1);
            let mut cur = std::io::Cursor::new(&wire[..cut]);
            read_frame(&mut cur, wire.len(), DEFAULT_MAX_FRAME_BYTES, || None).is_err()
        },
    );
}

// ------------------------------------------- plan canonicalization (PR 7)

use std::sync::Arc;

use theseus::cache::{canonicalize, fingerprint};
use theseus::cluster::client::connect;
use theseus::config::WorkerConfig;
use theseus::exec::plan::{AggFn, AggSpec, Pred};
use theseus::planner::Logical;
use theseus::sim::SimContext;
use theseus::storage::format::FileWriter;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::types::{DType, Field, Schema};

/// Order visibility at a node — the test's independent restatement of
/// the gating `theseus::cache` documents: which reorderings at this
/// node are invisible in the final result.
#[derive(Clone, Copy, PartialEq)]
enum RVis {
    /// Both column order and row order reach the result.
    Both,
    /// A name-addressed ancestor re-picks columns; row order survives.
    Rows,
    /// An Aggregate ancestor absorbs the input multiset entirely.
    Nothing,
}

impl RVis {
    fn cols_visible(self) -> bool {
        self == RVis::Both
    }
}

fn pick_col(rng: &mut Rng) -> String {
    ["a", "b", "c", "d", "e"][rng.gen_range(5) as usize].to_string()
}

fn rand_leaf_pred(rng: &mut Rng) -> Pred {
    match rng.gen_range(3) {
        0 => Pred::RangeI64 {
            col: pick_col(rng),
            lo: rng.gen_i64(0, 50),
            hi: rng.gen_i64(50, 100),
        },
        1 => Pred::EqI64 { col: pick_col(rng), val: rng.gen_i64(0, 9) },
        _ => Pred::RangeF32 { col: pick_col(rng), lo: 0.0, hi: rng.gen_f32(1.0, 9.0) },
    }
}

fn rand_pred(rng: &mut Rng) -> Pred {
    let n = 1 + rng.gen_range(3) as usize;
    (0..n).map(|_| rand_leaf_pred(rng)).reduce(|a, b| a.and(b)).unwrap()
}

fn shuffled<T: Clone>(rng: &mut Rng, xs: &[T]) -> Vec<T> {
    let mut v: Vec<T> = xs.to_vec();
    for i in (1..v.len()).rev() {
        let j = rng.gen_range(i as u64 + 1) as usize;
        v.swap(i, j);
    }
    v
}

fn rand_cols(rng: &mut Rng) -> Vec<String> {
    let base = ["a", "b", "c", "d", "e"].map(String::from);
    let n = 2 + rng.gen_range(3) as usize;
    shuffled(rng, &base).into_iter().take(n).collect()
}

/// Random `Logical` tree. Column/table names are free-floating — the
/// canonicalization property is purely structural, nothing here plans
/// or executes.
fn rand_tree(rng: &mut Rng, depth: usize) -> Logical {
    if depth == 0 || rng.gen_range(4) == 0 {
        let cols = rand_cols(rng);
        let refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let table = ["t1", "t2", "t3"][rng.gen_range(3) as usize];
        return if rng.gen_range(2) == 0 {
            Logical::scan_where(table, &refs, rand_pred(rng))
        } else {
            Logical::scan(table, &refs)
        };
    }
    match rng.gen_range(6) {
        0 => rand_tree(rng, depth - 1).filter(rand_pred(rng)),
        1 => {
            let cols = rand_cols(rng);
            let refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
            rand_tree(rng, depth - 1).project(&refs)
        }
        2 => {
            let n = 1 + rng.gen_range(3) as usize;
            let aggs = (0..n)
                .map(|_| {
                    let f = [AggFn::Sum, AggFn::Count, AggFn::Min, AggFn::Max]
                        [rng.gen_range(4) as usize];
                    AggSpec::new(f, pick_col(rng))
                })
                .collect();
            rand_tree(rng, depth - 1).aggregate(pick_col(rng), aggs)
        }
        3 => {
            let l = rand_tree(rng, depth - 1);
            let r = rand_tree(rng, depth - 1);
            let (lo, ro) = (pick_col(rng), pick_col(rng));
            l.join(r, lo, ro, rng.gen_range(2) == 0)
        }
        4 => rand_tree(rng, depth - 1).sort(pick_col(rng), rng.gen_range(2) == 0),
        _ => rand_tree(rng, depth - 1).limit(1 + rng.gen_range(20)),
    }
}

/// Apply a random *equivalence-preserving* rewrite, mirroring the
/// gating `canonicalize` documents: conjunct order is free everywhere;
/// column-list / agg-list order is free only below a name-addressed
/// ancestor; join inputs commute only under an Aggregate.
fn equiv_rewrite(rng: &mut Rng, q: &Logical, vis: RVis) -> Logical {
    let rw_pred = |rng: &mut Rng, p: &Pred| -> Pred {
        let leaves: Vec<Pred> = p.conjuncts().into_iter().cloned().collect();
        shuffled(rng, &leaves).into_iter().reduce(|a, b| a.and(b)).unwrap()
    };
    match q {
        Logical::Scan { table, cols, pred } => Logical::Scan {
            table: table.clone(),
            cols: if vis.cols_visible() { cols.clone() } else { shuffled(rng, cols) },
            pred: pred.as_ref().map(|p| rw_pred(rng, p)),
        },
        Logical::Filter { input, pred } => Logical::Filter {
            input: Box::new(equiv_rewrite(rng, input, vis)),
            pred: rw_pred(rng, pred),
        },
        Logical::Project { input, cols } => {
            let child = if vis == RVis::Nothing { RVis::Nothing } else { RVis::Rows };
            Logical::Project {
                input: Box::new(equiv_rewrite(rng, input, child)),
                cols: if vis.cols_visible() { cols.clone() } else { shuffled(rng, cols) },
            }
        }
        Logical::Aggregate { input, group_by, aggs } => Logical::Aggregate {
            input: Box::new(equiv_rewrite(rng, input, RVis::Nothing)),
            group_by: group_by.clone(),
            aggs: if vis.cols_visible() { aggs.clone() } else { shuffled(rng, aggs) },
        },
        Logical::Join { left, right, left_on, right_on, lip } => {
            let l = equiv_rewrite(rng, left, vis);
            let r = equiv_rewrite(rng, right, vis);
            if vis == RVis::Nothing && rng.gen_range(2) == 0 {
                Logical::Join {
                    left: Box::new(r),
                    right: Box::new(l),
                    left_on: right_on.clone(),
                    right_on: left_on.clone(),
                    lip: *lip,
                }
            } else {
                Logical::Join {
                    left: Box::new(l),
                    right: Box::new(r),
                    left_on: left_on.clone(),
                    right_on: right_on.clone(),
                    lip: *lip,
                }
            }
        }
        Logical::Sort { input, by, desc } => Logical::Sort {
            input: Box::new(equiv_rewrite(rng, input, vis)),
            by: by.clone(),
            desc: *desc,
        },
        Logical::Limit { input, n } => {
            Logical::Limit { input: Box::new(equiv_rewrite(rng, input, vis)), n: *n }
        }
        Logical::Fragment { .. } => q.clone(),
    }
}

#[test]
fn equivalent_rewrites_share_a_canonical_key() {
    check(
        0x5E21B6,
        400,
        |rng| (rng.next_u64() as i64, rng.next_u64() as i64),
        |&(tree_seed, rw_seed)| {
            let tree = rand_tree(&mut Rng::new(tree_seed as u64), 3);
            let rw = equiv_rewrite(&mut Rng::new(rw_seed as u64), &tree, RVis::Both);
            let ct = canonicalize(&tree);
            // same key for every member of the equivalence class, and
            // canonicalization is a projection (idempotent)
            fingerprint(&ct) == fingerprint(&canonicalize(&rw))
                && fingerprint(&ct) == fingerprint(&canonicalize(&ct))
        },
    );
}

/// Integer-valued fact table (exact, order-independent f64 sums).
fn int_fact_store(rows: usize) -> Arc<SimObjectStore> {
    let store = SimObjectStore::in_memory(&SimContext::test());
    let mut rng = Rng::new(23);
    let schema =
        Schema::new(vec![Field::new("k", DType::Int64), Field::new("v", DType::Int64)]);
    for f in 0..2 {
        let batch = RecordBatch::new(vec![
            Column::i64("k", (0..rows).map(|_| rng.gen_i64(0, 19)).collect()),
            Column::i64("v", (0..rows).map(|_| rng.gen_i64(0, 999)).collect()),
        ])
        .unwrap();
        let mut w = FileWriter::new(schema.clone(), Codec::Zstd { level: 1 }, 256);
        w.write(batch).unwrap();
        store.put(&format!("fact/part-{f}.ths"), &w.finish().unwrap()).unwrap();
    }
    store
}

#[test]
fn cached_results_are_byte_identical_to_uncached_execution() {
    let store = int_fact_store(1500);
    let plain = connect(
        WorkerConfig { num_workers: 2, ..WorkerConfig::test() },
        store.clone(),
        None,
    )
    .unwrap();
    let cached = connect(
        WorkerConfig {
            num_workers: 2,
            result_cache_bytes: 4 << 20,
            fragment_cache_bytes: 4 << 20,
            ..WorkerConfig::test()
        },
        store,
        None,
    )
    .unwrap();
    // Few iterations — each runs 4 distributed queries — but every one
    // checks cold, warm-exact, and rewritten-warm against the uncached
    // truth, byte for byte.
    check(
        0xB17E5,
        6,
        |rng| ((rng.gen_i64(0, 9), rng.gen_i64(10, 19)), rng.gen_range(8) as usize),
        |&((lo, hi), limit)| {
            let base = Logical::scan("fact", &["k", "v"])
                .filter(
                    Pred::RangeI64 { col: "k".into(), lo, hi }
                        .and(Pred::RangeI64 { col: "v".into(), lo: 0, hi: 900 }),
                )
                .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
                .sort("k", false);
            let q = if limit == 0 { base.clone() } else { base.clone().limit(limit as u64) };
            // same query, authored differently: conjuncts flipped,
            // scan columns swapped (both absorbed by the aggregate)
            let rw_base = Logical::scan("fact", &["v", "k"])
                .filter(
                    Pred::RangeI64 { col: "v".into(), lo: 0, hi: 900 }
                        .and(Pred::RangeI64 { col: "k".into(), lo, hi }),
                )
                .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
                .sort("k", false);
            let rw = if limit == 0 { rw_base } else { rw_base.limit(limit as u64) };
            let truth = plain.query(&q).unwrap().batch.encode();
            let cold = cached.query(&q).unwrap().batch.encode();
            let warm = cached.query(&q).unwrap().batch.encode();
            let warm_rw = cached.query(&rw).unwrap().batch.encode();
            truth == cold && truth == warm && truth == warm_rw
        },
    );
}

// -------------------------------------------- admission fairness (PR 8)

use theseus::cluster::AdmissionQueue;

/// One step against the gateway's pure admission policy.
#[derive(Clone, Debug)]
enum AdmitOp {
    /// A query arrives with an admission class and a scan footprint.
    Arrive { priority: i64, bytes: usize },
    /// The oldest admitted query finishes and returns its bytes.
    Finish,
}

impl Shrink for AdmitOp {
    fn shrink(&self) -> Vec<AdmitOp> {
        match self {
            AdmitOp::Arrive { priority, bytes } => {
                let mut out = Vec::new();
                if *bytes > 1 {
                    out.push(AdmitOp::Arrive { priority: *priority, bytes: bytes / 2 });
                }
                if *priority > 0 {
                    out.push(AdmitOp::Arrive { priority: priority / 2, bytes: *bytes });
                }
                out
            }
            AdmitOp::Finish => Vec::new(),
        }
    }
}

#[derive(Clone, Debug)]
struct AdmitCase {
    capacity: usize,
    limit: usize,
    ops: Vec<AdmitOp>,
}

impl Shrink for AdmitCase {
    fn shrink(&self) -> Vec<AdmitCase> {
        let mut out: Vec<AdmitCase> = self
            .ops
            .shrink()
            .into_iter()
            .map(|ops| AdmitCase { capacity: self.capacity, limit: self.limit, ops })
            .collect();
        if self.limit > 1 {
            out.push(AdmitCase {
                capacity: self.capacity,
                limit: self.limit - 1,
                ops: self.ops.clone(),
            });
        }
        if self.capacity > 1 {
            out.push(AdmitCase {
                capacity: self.capacity / 2,
                limit: self.limit,
                ops: self.ops.clone(),
            });
        }
        out
    }
}

fn gen_admit_case(rng: &mut Rng) -> AdmitCase {
    let n = rng.gen_range(24) as usize + 4;
    let ops = (0..n)
        .map(|_| match rng.gen_range(4) {
            0..=2 => AdmitOp::Arrive {
                priority: rng.gen_range(3) as i64,
                bytes: rng.gen_range(80) as usize + 1,
            },
            _ => AdmitOp::Finish,
        })
        .collect();
    AdmitCase {
        capacity: rng.gen_range(96) as usize + 16,
        limit: rng.gen_range(3) as usize + 1,
        ops,
    }
}

/// Admit everything that fits, checking after each admission that the
/// budget holds, no same-class younger ticket overtook an older one,
/// and no waiter's bypass count exceeds the starvation bound.
fn admit_pump(
    q: &mut AdmissionQueue,
    limit: usize,
    prio_of: &std::collections::HashMap<u64, i64>,
    running: &mut std::collections::VecDeque<u64>,
    last_in_class: &mut std::collections::HashMap<i64, u64>,
) -> bool {
    while let Some(t) = q.try_admit() {
        if q.admitted_bytes() > q.capacity() {
            return false; // aggregate admitted bytes exceeded the budget
        }
        let p = prio_of[&t];
        if last_in_class.get(&p).is_some_and(|&prev| prev > t) {
            return false; // admitted-order inversion within a class
        }
        last_in_class.insert(p, t);
        running.push_back(t);
    }
    // starvation bound: bypassed never exceeds the limit for anyone
    q.waiting_snapshot().iter().all(|&(_, _, by)| by <= limit)
}

fn admit_case_holds(case: &AdmitCase) -> bool {
    let mut q = AdmissionQueue::new(case.capacity, case.limit);
    let limit = case.limit.max(1);
    let mut prio_of: std::collections::HashMap<u64, i64> = Default::default();
    // admitted-but-unfinished, in admission order (Finish pops oldest)
    let mut running: std::collections::VecDeque<u64> = Default::default();
    let mut last_in_class: std::collections::HashMap<i64, u64> = Default::default();

    for op in &case.ops {
        match op {
            AdmitOp::Arrive { priority, bytes } => {
                let t = q.arrive(*priority, *bytes);
                prio_of.insert(t, *priority);
            }
            AdmitOp::Finish => {
                if let Some(t) = running.pop_front() {
                    q.release(t);
                }
            }
        }
        if !admit_pump(&mut q, limit, &prio_of, &mut running, &mut last_in_class) {
            return false;
        }
    }

    // Liveness: finish the admitted queries one at a time; every
    // waiter must be admitted along the way. Footprints are clamped
    // to the capacity on arrival, so once the budget is empty the
    // candidate always fits — if the queue ever stalls with nothing
    // running, someone was starved outright.
    let mut guard = 2 * case.ops.len() + 8;
    while q.waiting_len() > 0 {
        guard = match guard.checked_sub(1) {
            Some(g) => g,
            None => return false, // no forward progress
        };
        let before = q.waiting_len();
        if !admit_pump(&mut q, limit, &prio_of, &mut running, &mut last_in_class) {
            return false;
        }
        if q.waiting_len() == before {
            match running.pop_front() {
                Some(t) => q.release(t),
                None => return false, // empty budget, yet nobody admitted
            }
        }
    }
    for t in running.drain(..) {
        q.release(t);
    }
    q.admitted_bytes() == 0
}

#[test]
fn admission_is_fair_bounded_and_always_drains() {
    check(0xAD317, 400, gen_admit_case, admit_case_holds);
}

// ---------------------------------------------- bounded retry (PR 10)

use theseus::fault::{self, RetryPolicy};

/// Scripted attempt outcomes for one `with_retry` call: 0 = success,
/// 1 = transient failure, 2 = permanent failure (ops past the script's
/// end succeed).
#[derive(Clone, Debug)]
struct RetryCase {
    limit: usize,
    script: Vec<u8>,
}

impl Shrink for RetryCase {
    fn shrink(&self) -> Vec<RetryCase> {
        let mut out: Vec<RetryCase> = self
            .script
            .shrink()
            .into_iter()
            .map(|script| RetryCase { limit: self.limit, script })
            .collect();
        if self.limit > 0 {
            out.push(RetryCase { limit: self.limit - 1, script: self.script.clone() });
        }
        out
    }
}

fn gen_retry_case(rng: &mut Rng) -> RetryCase {
    let n = rng.gen_range(8) as usize;
    RetryCase {
        limit: rng.gen_range(5) as usize,
        script: (0..n).map(|_| rng.gen_range(3) as u8).collect(),
    }
}

/// `with_retry` against an attempt-by-attempt model: transient failures
/// retry (each one counted) up to the limit, the first success or
/// permanent failure stops the ladder, classification survives the way
/// out, and the op is called exactly as many times as the model says.
fn retry_case_holds(case: &RetryCase) -> bool {
    let metrics = Arc::new(Metrics::default());
    let mut calls = 0usize;
    let res: theseus::Result<u32> = fault::with_retry(
        RetryPolicy { limit: case.limit, base_ms: 0 },
        Some(&metrics),
        "prop",
        || {
            let out = case.script.get(calls).copied().unwrap_or(0);
            calls += 1;
            match out {
                0 => Ok(7),
                1 => Err(Error::Transient { site: "prop", detail: "scripted".into() }),
                _ => Err(theseus::Error::internal("scripted permanent")),
            }
        },
    );

    // the model: attempts run 1..=max(limit, 1); a transient outcome
    // retries (counted) unless it was the last allowed attempt
    let limit = case.limit.max(1);
    let mut want_calls = 0usize;
    let mut want_retries = 0u64;
    let mut want = 0u8;
    for attempt in 1..=limit {
        want_calls = attempt;
        want = case.script.get(attempt - 1).copied().unwrap_or(0);
        match want {
            1 if attempt < limit => want_retries += 1,
            _ => break,
        }
    }

    if calls != want_calls {
        return false;
    }
    if metrics.counter_value("retry.attempts_total") != want_retries {
        return false;
    }
    match (want, res) {
        (0, Ok(7)) => true,
        // exhausted transient stays transient (the gateway rung decides)
        (1, Err(e)) => e.is_transient() && e.is_retryable(),
        // permanent failures are never retried and never retryable
        (2, Err(e)) => !e.is_transient() && !e.is_retryable(),
        _ => false,
    }
}

#[test]
fn retry_ladder_matches_scripted_model() {
    check(0xFA017, 300, gen_retry_case, retry_case_holds);
}

#[test]
fn backoff_is_a_pure_growing_capped_function() {
    check(
        0xBAC0FF,
        200,
        |rng| (rng.gen_range(50) + 1, rng.gen_range(5) as usize + 1),
        |&(base, attempt)| {
            let d = fault::backoff("prop", attempt, base);
            // pure: same (site, attempt, base) -> same delay
            if d != fault::backoff("prop", attempt, base) {
                return false;
            }
            // zero base never sleeps
            if fault::backoff("prop", attempt, 0) != std::time::Duration::ZERO {
                return false;
            }
            // strictly grows below the 32x cap, and never exceeds
            // cap + jitter
            let next = fault::backoff("prop", attempt + 1, base);
            (attempt >= 6 || next > d)
                && d <= std::time::Duration::from_millis(base * 32 + base / 2)
        },
    );
}
