//! Deterministic pressure-cycle integration test (residency-aware
//! scheduling, §3.3.1).
//!
//! A small scan→join→agg plan runs through the sim worker twice:
//!
//! * a no-pressure baseline — roomy arena, residency bonus table
//!   zeroed;
//! * a pressure run — an arena sized to force demotions, the full
//!   demote→spill→promote chain driven deterministically between pops,
//!   and the residency bonus table enabled.
//!
//! Query results must be byte-identical, and the pressure run must
//! show at least one residency-driven re-rank
//! (`sched.residency_rerank_total > 0`).
//!
//! The inline harness is single-threaded — fixed RNG seed, fixed poll /
//! pop / cycle interleaving — so the run is exactly reproducible; a
//! second test pushes the same plan through the threaded cluster
//! (executors + movement plane live) to exercise the asynchronous loop
//! end-to-end.

use std::sync::Arc;

use theseus::cluster::client::connect;
use theseus::config::WorkerConfig;
use theseus::exec::plan::{AggFn, AggSpec, OpSpec, PhysicalPlan};
use theseus::exec::{QueryDag, WorkerCtx};
use theseus::executors::compute::{ResidencyBonus, TaskQueue};
use theseus::executors::movement::HolderRegistry;
use theseus::executors::network::Router;
use theseus::memory::{BatchHolder, Tier};
use theseus::metrics::Metrics;
use theseus::planner::Logical;
use theseus::sim::SimContext;
use theseus::storage::compression::Codec;
use theseus::storage::format::FileWriter;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::types::{Column, DType, Field, RecordBatch, Schema};
use theseus::util::rng::Rng;

const SEED: u64 = 42;
const KEYS: i64 = 40;

/// Write the fact and dim tables from a fixed seed into `store`.
fn write_tables(store: &dyn ObjectStore) {
    let mut rng = Rng::new(SEED);
    let fact_schema = Schema::new(vec![
        Field::new("k", DType::Int64),
        Field::new("v", DType::Float32),
    ]);
    for f in 0..2 {
        let rows = 1500;
        let batch = RecordBatch::new(vec![
            Column::i64("k", (0..rows).map(|_| rng.gen_i64(0, KEYS - 1)).collect()),
            Column::f32("v", (0..rows).map(|_| rng.gen_f32(-100.0, 100.0)).collect()),
        ])
        .unwrap();
        let mut w = FileWriter::new(fact_schema.clone(), Codec::Zstd { level: 1 }, 256);
        w.write(batch).unwrap();
        store.put(&format!("fact/{f}.ths"), &w.finish().unwrap()).unwrap();
    }
    let dim_schema = Schema::new(vec![
        Field::new("dk", DType::Int64),
        Field::new("w", DType::Int64),
    ]);
    let batch = RecordBatch::new(vec![
        Column::i64("dk", (0..KEYS).collect()),
        Column::i64("w", (0..KEYS).map(|i| i * 7).collect()),
    ])
    .unwrap();
    let mut w = FileWriter::new(dim_schema, Codec::None, 64);
    w.write(batch).unwrap();
    store.put("dim/0.ths", &w.finish().unwrap()).unwrap();
}

/// scan(dim) + scan(fact) → hash join on dk = k → group by dk.
/// Count/min/max aggregates only: exact in any absorption order, so
/// results are bitwise comparable across schedules.
fn plan() -> PhysicalPlan {
    let mut p = PhysicalPlan::new();
    let dim = p.add(
        OpSpec::Scan { table: "dim".into(), cols: vec!["dk".into(), "w".into()], pred: None },
        vec![],
    );
    let fact = p.add(
        OpSpec::Scan { table: "fact".into(), cols: vec!["k".into(), "v".into()], pred: None },
        vec![],
    );
    let join = p.add(
        OpSpec::HashJoin { left_on: "dk".into(), right_on: "k".into(), lip: false },
        vec![dim, fact],
    );
    p.add(
        OpSpec::HashAgg {
            group_by: "dk".into(),
            aggs: vec![
                AggSpec::new(AggFn::Count, "v"),
                AggSpec::new(AggFn::Min, "v"),
                AggSpec::new(AggFn::Max, "w"),
            ],
        },
        vec![join],
    );
    p
}

#[derive(Default)]
struct CycleCounts {
    demoted: u64,
    spilled: u64,
    promoted: u64,
}

/// Drive one full demote→spill→promote chain on `holder`, raising a
/// ResidencyChanged notification after every completed move — the
/// deterministic stand-in for the Data-Movement executor's movers.
fn force_cycle(holder: &BatchHolder, queue: &TaskQueue, counts: &mut CycleCounts) {
    if holder.demote_one(Tier::Device).unwrap() > 0 {
        counts.demoted += 1;
        queue.notify_residency_changed(holder.id());
    }
    if holder.demote_one(Tier::Host).unwrap() > 0 {
        counts.spilled += 1;
        queue.notify_residency_changed(holder.id());
    }
    if holder.promote_one().unwrap() {
        counts.promoted += 1;
        queue.notify_residency_changed(holder.id());
    }
}

/// Free device memory the way the movement plane would, so a retryable
/// OOM pop can succeed: demote device-resident batches until a healthy
/// amount is free (coldest-holder order not needed for correctness).
fn free_device(holders: &HolderRegistry, queue: &TaskQueue) {
    let mut freed = 0usize;
    loop {
        let mut victims = Vec::new();
        holders.for_each(|_, _, h| {
            if h.stats().device_batches > 0 {
                victims.push(h.clone());
            }
        });
        let mut progress = false;
        for v in victims {
            let n = v.demote_one(Tier::Device).unwrap();
            if n > 0 {
                freed += n;
                progress = true;
                queue.notify_residency_changed(v.id());
            }
        }
        if !progress || freed >= 16 << 10 {
            break;
        }
    }
}

/// Run `plan()` through the inline sim worker. `pressure` enables the
/// forced movement cycle; the bonus table rides in `bonus`.
fn run_inline(
    device_capacity: usize,
    bonus: ResidencyBonus,
    pressure: bool,
    metrics: Arc<Metrics>,
) -> (RecordBatch, CycleCounts, u64) {
    let cfg = WorkerConfig {
        device_capacity,
        batch_rows: 128,
        ..WorkerConfig::test()
    };
    let ctx = WorkerCtx::test_with(Arc::new(cfg));
    write_tables(ctx.store.as_ref());
    let router = Arc::new(Router::new());
    let holders = HolderRegistry::new();
    let queue = TaskQueue::with_residency(bonus, metrics.clone());
    let dag = QueryDag::build(&plan(), &ctx, &router, &holders, 1).unwrap();

    let mut counts = CycleCounts::default();
    let mut converged = false;
    for _ in 0..20_000 {
        let tasks = dag.poll(&ctx).unwrap();
        // pick the cycle target *before* submitting: an input holder of
        // a task that is about to sit in the queue, so the re-rank is
        // guaranteed to see an affected entry
        let cycle_target = if pressure {
            tasks
                .iter()
                .find(|t| {
                    t.inputs
                        .first()
                        .map(|h| h.stats().device_batches > 0)
                        .unwrap_or(false)
                })
                .map(|t| t.inputs[0].clone())
        } else {
            None
        };
        for t in tasks {
            queue.submit(t);
        }
        if let Some(h) = cycle_target {
            force_cycle(&h, &queue, &mut counts);
        }
        while let Some(mut task) = queue.try_pop() {
            match (task.run)(&ctx) {
                Ok(()) => {}
                Err(e) if e.is_retryable() && task.attempts < 12 => {
                    free_device(&holders, &queue);
                    task.attempts += 1;
                    queue.submit(task);
                }
                Err(e) => panic!("task op {} failed: {e}", task.op),
            }
        }
        if dag.all_done() {
            converged = true;
            break;
        }
    }
    assert!(converged, "inline driver did not converge");

    let mut parts = Vec::new();
    let mut oom_retries = 0;
    loop {
        match dag.output.pop_device() {
            Ok(Some(db)) => parts.push(db.batch.clone()),
            Ok(None) => break,
            Err(e) if e.is_retryable() && oom_retries < 12 => {
                free_device(&holders, &queue);
                oom_retries += 1;
            }
            Err(e) => panic!("draining output: {e}"),
        }
    }
    let demotions = ctx.env.demotions();
    (RecordBatch::concat(&parts).unwrap(), counts, demotions)
}

#[test]
fn pressure_cycle_is_deterministic_and_reranks() {
    let bonus = ResidencyBonus { device_bonus: 40, spilled_penalty: 160, rerank_batch: 16 };

    // no-pressure baseline: roomy arena, residency ordering off
    let base_metrics = Arc::new(Metrics::default());
    let (baseline, _, _) =
        run_inline(64 << 20, ResidencyBonus::default(), false, base_metrics.clone());
    assert_eq!(baseline.rows() as i64, KEYS, "every dim key joins");
    assert_eq!(
        base_metrics.gauge_value("sched.residency_rerank_total"),
        0,
        "zeroed bonus table must never re-rank"
    );

    // pressure run: ~48 KiB arena + forced demote→spill→promote chains
    let metrics = Arc::new(Metrics::default());
    let (result, counts, demotions) = run_inline(48 << 10, bonus, true, metrics.clone());

    // Snapshot the gauges for the CI failure artifact *before* any
    // assertion can panic — a post-assert write would never run on the
    // failures it exists to explain.
    let reranks = metrics.gauge_value("sched.residency_rerank_total");
    let _ = std::fs::create_dir_all("target");
    let _ = std::fs::write(
        "target/pressure_cycle_metrics.txt",
        format!(
            "inline pressure run\nreranks: {reranks}\nstall_avoided: {}\ndemoted: {} \
             spilled: {} promoted: {}\nenv demotions: {demotions}\n\n{}",
            metrics.gauge_value("sched.spill_stall_avoided"),
            counts.demoted,
            counts.spilled,
            counts.promoted,
            metrics.snapshot()
        ),
    );

    // the full movement cycle actually happened
    assert!(counts.demoted > 0, "no device→host demotion forced");
    assert!(counts.spilled > 0, "no host→disk spill forced");
    assert!(counts.promoted > 0, "no disk→host promotion forced");
    assert!(demotions > 0, "tiny arena must demote on push");

    // at least one residency-driven re-rank was observed by the queue
    assert!(reranks > 0, "no residency re-rank despite forced cycles");

    // and the answer is byte-identical to the no-pressure run
    assert_eq!(
        result.encode(),
        baseline.encode(),
        "pressure run altered the query result"
    );
}

/// Same plan through the real threaded cluster: compute, movement,
/// pre-load, and network executors all live, arena sized to spill. The
/// asynchronous interleaving varies, but count/min/max results must
/// still match the roomy run bit-for-bit.
#[test]
fn threaded_worker_under_pressure_matches_roomy_run() {
    let query = || {
        Logical::scan("dim", &["dk", "w"])
            .join(Logical::scan("fact", &["k", "v"]), "dk", "k", false)
            .aggregate(
                "dk",
                vec![
                    AggSpec::new(AggFn::Count, "v"),
                    AggSpec::new(AggFn::Min, "v"),
                    AggSpec::new(AggFn::Max, "w"),
                ],
            )
            .sort("dk", false)
    };
    let run = |cfg: WorkerConfig| {
        let store = SimObjectStore::in_memory(&SimContext::test());
        write_tables(store.as_ref());
        let client = connect(cfg, store, None).unwrap();
        client.query(&query()).unwrap()
    };

    let roomy = run(WorkerConfig { num_workers: 2, ..WorkerConfig::test() });
    let tight = run(WorkerConfig {
        num_workers: 2,
        device_capacity: 48 << 10,
        spill_watermark: 0.5,
        residency_bonus_device: 40,
        residency_penalty_spilled: 160,
        residency_rerank_batch: 16,
        ..WorkerConfig::test()
    });
    assert!(tight.total_spills() > 0, "48 KiB arena must spill");
    assert_eq!(roomy.batch.rows() as i64, KEYS);
    assert_eq!(
        tight.batch.encode(),
        roomy.batch.encode(),
        "spilling run altered the query result"
    );
}

/// Deterministic pressure-driven shuffle flush: a hash-partition
/// exchange buffering rows *below* its flush threshold must drain the
/// moment the worker's memory-pressure epoch advances — buffered
/// shuffle state never deepens a spill cycle. The raise is performed by
/// hand on the installed `PressureEvent` (the exact hook the
/// Data-Movement plane's tiers signal), so the trigger point is exactly
/// reproducible.
#[test]
fn pressure_event_flushes_buffered_shuffle_early() {
    use std::time::Duration;
    use theseus::config::TransportKind;
    use theseus::exec::operators::{ExchangeOp, Operator};
    use theseus::exec::plan::ExchangeRole;
    use theseus::executors::network::{ChannelRx, NetworkExecutor, Outbox};
    use theseus::memory::PressureEvent;
    use theseus::network::InprocHub;

    const ROWS: i64 = 256;
    let cfg = WorkerConfig {
        num_workers: 1,
        exchange_estimate_batches: 1,
        exchange_flush_bytes: 1 << 30, // size-triggered flush never fires
        ..WorkerConfig::test()
    };
    let mut ctx = WorkerCtx::test_with(Arc::new(cfg));
    // The Data-Movement executor installs this at worker bring-up; the
    // test holds the event itself so the raise is exactly timed.
    let event = PressureEvent::new();
    ctx.env.arena.install_pressure(event.clone(), 1.0);

    let hub = InprocHub::new(1, &SimContext::test(), TransportKind::Tcp);
    let ep = hub.endpoints().remove(0);
    let router = Arc::new(Router::new());
    let outbox = Arc::new(Outbox::new(64));
    let net = NetworkExecutor::start(
        Arc::new(ep),
        outbox.clone(),
        router.clone(),
        None,
        None,
        1,
    );
    ctx.outbox = outbox;

    let rx_holder = BatchHolder::new("rx", ctx.env.clone());
    let rx = Arc::new(ChannelRx::new(rx_holder.clone(), 1));
    router.register(9, rx.clone());

    let input = BatchHolder::new("in", ctx.env.clone());
    let pending = BatchHolder::new("pending", ctx.env.clone());
    let batch = RecordBatch::new(vec![
        Column::i64("k", (0..ROWS).collect()),
        Column::i64("w", (0..ROWS).map(|i| i * 3).collect()),
    ])
    .unwrap();
    input.push_batch_host(batch.clone()).unwrap();
    input.push_batch_host(batch.clone()).unwrap();

    let op = ExchangeOp::new(
        0,
        1000,
        2,
        input.clone(),
        pending,
        rx,
        9,
        "k".into(),
        ExchangeRole::Shuffle,
        None,
        None,
    );

    // reach Stream and buffer both batches (far below the threshold)
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while op.buffered_shuffle_rows() < 2 * ROWS as usize {
        assert!(std::time::Instant::now() < deadline, "never buffered the rows");
        for t in op.poll(&ctx).unwrap() {
            (t.run)(&ctx).unwrap();
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(ctx.metrics.counter_value("exchange.flush_total"), 0);
    assert_eq!(op.sent_batches(), 0, "nothing crossed the wire yet");

    // one memory-pressure raise -> the very next poll drains the buffers
    event.raise_host(1);
    for t in op.poll(&ctx).unwrap() {
        (t.run)(&ctx).unwrap();
    }
    assert_eq!(
        ctx.metrics.counter_value("exchange.pressure_flush_total"),
        1,
        "the epoch advance must flush the buffered destination"
    );
    assert_eq!(ctx.metrics.counter_value("exchange.flush_total"), 1);
    assert_eq!(
        ctx.metrics.counter_value("exchange.coalesced_bytes"),
        2 * batch.byte_size() as u64
    );
    assert_eq!(op.buffered_shuffle_rows(), 0);
    assert_eq!(op.sent_batches(), 1, "both buffered batches left as ONE frame");

    // the drained rows arrive intact, and the stream completes cleanly
    input.finish();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while !op.is_done() {
        assert!(std::time::Instant::now() < deadline, "exchange stalled");
        for t in op.poll(&ctx).unwrap() {
            (t.run)(&ctx).unwrap();
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(net.flush(Duration::from_secs(2)));
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    while !rx_holder.is_finished() {
        assert!(std::time::Instant::now() < deadline, "finish lost");
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut got = Vec::new();
    while let Some(db) = rx_holder.pop_device().unwrap() {
        got.push(db.batch.clone());
    }
    let got = RecordBatch::concat(&got).unwrap();
    let want = RecordBatch::concat(&[batch.clone(), batch]).unwrap();
    assert_eq!(
        got.encode(),
        want.encode(),
        "pressure flush altered the shuffled rows"
    );
    net.stop();
}

/// Deterministic slow receiver (§3.3 credit-based backpressure): with a
/// credit window of W, at most W data frames may cross the wire before
/// the consumer drains — the rest stay *queued in the sender's outbox*
/// (depth bounded by the window) instead of ballooning the receiver.
/// The stall is visible on `exchange.credit_stall_total`; draining the
/// holder returns credits through the live receiver thread and the
/// blocked tail (Finish included, held FIFO behind it) then crosses
/// byte-identically.
#[test]
fn slow_receiver_bounds_outbox_depth_via_credits() {
    use std::time::Duration;
    use theseus::config::TransportKind;
    use theseus::executors::network::{ChannelRx, NetworkExecutor, Outbox};
    use theseus::network::InprocHub;

    const N: usize = 6;
    const WINDOW: usize = 2;
    const ROWS: i64 = 64;

    let ctx = WorkerCtx::test();
    let hub = InprocHub::new(1, &SimContext::test(), TransportKind::Tcp);
    let ep = hub.endpoints().remove(0);
    let metrics = Arc::new(Metrics::default());
    let router = Arc::new(Router::new());
    router.install_metrics(metrics.clone());
    let outbox = Arc::new(Outbox::new(64));
    outbox.enable_credits(WINDOW);
    outbox.install_metrics(metrics.clone());
    let net = NetworkExecutor::start(
        Arc::new(ep),
        outbox.clone(),
        router.clone(),
        None,
        None,
        1,
    );

    let rx_holder = BatchHolder::new("rx", ctx.env.clone());
    let rx = Arc::new(ChannelRx::new(rx_holder.clone(), 1));
    router.register(9, rx.clone());

    // distinct, ordered batches so reordering or loss is visible
    let batches: Vec<RecordBatch> = (0..N as i64)
        .map(|i| {
            RecordBatch::new(vec![Column::i64(
                "k",
                (i * ROWS..(i + 1) * ROWS).collect(),
            )])
            .unwrap()
        })
        .collect();
    for b in &batches {
        outbox.send_encoded(0, 9, b.encode()).unwrap();
    }
    outbox.send_finish(0, 9).unwrap();

    let held = |h: &BatchHolder| {
        let s = h.stats();
        s.device_batches + s.host_batches + s.disk_batches
    };
    // exactly the startup window crosses; the consumer never drains, so
    // no credits come back and the lane stalls on the third frame
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while held(&rx_holder) < WINDOW {
        assert!(std::time::Instant::now() < deadline, "window never delivered");
        std::thread::sleep(Duration::from_millis(2));
    }
    // settle: with zero credits remaining nothing further may cross
    std::thread::sleep(Duration::from_millis(150));
    let stalls = metrics.counter_value("exchange.credit_stall_total");
    let depth = outbox.len();
    let delivered_early = held(&rx_holder);

    // CI failure artifact, written before any assertion can panic
    let _ = std::fs::create_dir_all("target");
    let _ = std::fs::write(
        "target/credit_backpressure_metrics.txt",
        format!(
            "slow receiver, window {WINDOW}, {N} batches\nstalls: {stalls}\n\
             outbox depth at stall: {depth}\ndelivered before drain: {delivered_early}\n\n{}",
            metrics.snapshot()
        ),
    );

    assert_eq!(delivered_early, WINDOW, "credit window overrun");
    assert_eq!(
        depth,
        N - WINDOW + 1,
        "outbox must retain the blocked tail (data + Finish)"
    );
    assert!(stalls > 0, "stalled lane must show on exchange.credit_stall_total");
    assert_eq!(outbox.credits_remaining(0), Some(0));
    assert!(!rx_holder.is_finished(), "Finish must not overtake blocked data");

    // drain like a real consumer: every pop frees holder capacity, the
    // receiver thread grants credits back, and the lane resumes
    let mut got = Vec::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while got.len() < N {
        assert!(std::time::Instant::now() < deadline, "drain stalled");
        match rx_holder.pop_device().unwrap() {
            Some(db) => got.push(db.batch.clone()),
            None => std::thread::sleep(Duration::from_millis(2)),
        }
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while !rx_holder.is_finished() {
        assert!(std::time::Instant::now() < deadline, "finish lost");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(
        metrics.counter_value("net.credits_granted_total") >= (N - WINDOW) as u64,
        "the receiver must have granted the blocked frames their credits"
    );
    let got = RecordBatch::concat(&got).unwrap();
    let want = RecordBatch::concat(&batches).unwrap();
    assert_eq!(
        got.encode(),
        want.encode(),
        "backpressure altered or reordered the shuffled rows"
    );
    net.stop();
}

// ---------------------------------------------------- serving cache (PR 7)

use theseus::exec::plan::Pred;
use theseus::types::ColumnData;

/// Integer-valued fact table: f64 sums of integers below 2^53 are exact
/// and order-independent, so byte-level comparisons are deterministic.
fn write_int_fact(store: &dyn ObjectStore, files: usize, rows: usize) {
    let mut rng = Rng::new(SEED);
    let schema =
        Schema::new(vec![Field::new("k", DType::Int64), Field::new("v", DType::Int64)]);
    for f in 0..files {
        let batch = RecordBatch::new(vec![
            Column::i64("k", (0..rows).map(|_| rng.gen_i64(0, 9)).collect()),
            Column::i64("v", (0..rows).map(|_| rng.gen_i64(0, 99)).collect()),
        ])
        .unwrap();
        let mut w = FileWriter::new(schema.clone(), Codec::Zstd { level: 1 }, 256);
        w.write(batch).unwrap();
        store.put(&format!("facts/{f}.ths"), &w.finish().unwrap()).unwrap();
    }
}

fn sum_for_key(batch: &RecordBatch, key: i64) -> f64 {
    let ks = match &batch.columns[0].data {
        ColumnData::I64(v) => v,
        other => panic!("unexpected key column {other:?}"),
    };
    let row = ks.iter().position(|&k| k == key).expect("key present");
    match &batch.columns[1].data {
        ColumnData::F64(v) => v[row],
        other => panic!("unexpected sum column {other:?}"),
    }
}

/// The full deterministic invalidation cycle: warm hit (zero tasks) →
/// datasource write bumps the table version → next lookup misses and
/// recomputes fresh bytes → the refilled entry serves warm again.
#[test]
fn serving_cache_invalidation_cycle() {
    let store = SimObjectStore::in_memory(&SimContext::test());
    write_int_fact(&*store, 2, 1200);
    let client = connect(
        WorkerConfig {
            num_workers: 2,
            result_cache_bytes: 4 << 20,
            fragment_cache_bytes: 4 << 20,
            ..WorkerConfig::test()
        },
        store.clone(),
        None,
    )
    .unwrap();
    let q = Logical::scan("facts", &["k", "v"])
        .filter(Pred::RangeI64 { col: "k".into(), lo: 0, hi: 10 })
        .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
        .sort("k", false);

    let cold = client.query(&q).unwrap();
    assert!(!cold.worker_stats.is_empty(), "cold run must hit the cluster");
    let warm = client.query(&q).unwrap();
    assert!(warm.worker_stats.is_empty(), "warm exact hit must skip the cluster");
    assert_eq!(cold.batch.encode(), warm.batch.encode());

    // append 64 rows of (k=3, v=5) — bumps table "facts"
    let add = RecordBatch::new(vec![
        Column::i64("k", vec![3; 64]),
        Column::i64("v", vec![5; 64]),
    ])
    .unwrap();
    let schema =
        Schema::new(vec![Field::new("k", DType::Int64), Field::new("v", DType::Int64)]);
    let mut w = FileWriter::new(schema, Codec::Zstd { level: 1 }, 256);
    w.write(add).unwrap();
    store.put("facts/2.ths", &w.finish().unwrap()).unwrap();

    let fresh = client.query(&q).unwrap();
    assert!(!fresh.worker_stats.is_empty(), "version bump must force a miss");
    assert_ne!(cold.batch.encode(), fresh.batch.encode());
    let expect = sum_for_key(&cold.batch, 3) + 64.0 * 5.0;
    let got = sum_for_key(&fresh.batch, 3);
    assert!((got - expect).abs() < 1e-9, "fresh sum {got} != {expect}");

    let rewarm = client.query(&q).unwrap();
    assert!(rewarm.worker_stats.is_empty(), "refilled entry must serve warm");
    assert_eq!(fresh.batch.encode(), rewarm.batch.encode());
    let cache = client.gateway().cache.as_ref().unwrap();
    assert!(cache.metrics().counter_value("cache.invalidated") >= 1);
}

/// A tiny result budget must *evict* under sustained distinct traffic —
/// never wedge, never serve wrong bytes — and the bytes gauge must stay
/// within the governor-backed budget.
#[test]
fn serving_cache_tiny_budget_evicts_instead_of_wedging() {
    let store = SimObjectStore::in_memory(&SimContext::test());
    write_int_fact(&*store, 2, 1200);
    let plain = connect(
        WorkerConfig { num_workers: 2, ..WorkerConfig::test() },
        store.clone(),
        None,
    )
    .unwrap();
    let cached = connect(
        WorkerConfig {
            num_workers: 2,
            result_cache_bytes: 1024,
            ..WorkerConfig::test()
        },
        store,
        None,
    )
    .unwrap();
    // 12 distinct 10-group results: far more result bytes than the
    // 1 KiB budget admits at once
    for i in 0..12i64 {
        let q = Logical::scan("facts", &["k", "v"])
            .filter(Pred::RangeI64 { col: "v".into(), lo: i * 8, hi: i * 8 + 8 })
            .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
            .sort("k", false);
        let want = plain.query(&q).unwrap().batch.encode();
        let got = cached.query(&q).unwrap().batch.encode();
        assert_eq!(want, got, "slice {i}: eviction churn corrupted a result");
    }
    let cache = cached.gateway().cache.as_ref().unwrap();
    let m = cache.metrics();
    assert!(
        m.counter_value("cache.result_evict") >= 1,
        "12 distinct results through 1 KiB must evict"
    );
    assert!(
        m.gauge_value("cache.result_bytes") <= 1024,
        "resident bytes above the governor budget"
    );
}

// --------------------------------------- concurrent gateway (PR 8)

use std::time::{Duration, Instant};

use theseus::cluster::AdmissionController;

/// Distinct drill-down per index (different filter range ⇒ different
/// plan, result, and cache key).
fn facts_drill(i: i64) -> Logical {
    Logical::scan("facts", &["k", "v"])
        .filter(Pred::RangeI64 { col: "v".into(), lo: 0, hi: 20 + i * 10 })
        .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
        .sort("k", false)
}

fn facts_client(cfg: WorkerConfig) -> (Arc<SimObjectStore>, theseus::cluster::Client) {
    let store = SimObjectStore::in_memory(&SimContext::test());
    write_int_fact(&*store, 2, 1200);
    let client = connect(cfg, store.clone(), None).unwrap();
    (store, client)
}

/// N overlapping submissions must return byte-identical results to a
/// serial run of the same queries, and the per-query WorkerStats
/// scopes must partition the workers' global counters exactly — no
/// cross-query bleed (the seed's snapshot/delta scheme read
/// worker-lifetime totals, so overlapping queries double-counted each
/// other's tasks, and its cluster-wide `reset()` dropped live holders
/// of in-flight queries).
#[test]
fn concurrent_submissions_match_serial_and_stats_partition() {
    const N: usize = 4;
    let tasks_of = |r: &theseus::cluster::QueryResult| -> u64 {
        r.worker_stats.iter().map(|s| s.tasks_executed).sum()
    };

    // serial reference on its own cluster
    let (_, serial) = facts_client(WorkerConfig { num_workers: 2, ..WorkerConfig::test() });
    let want: Vec<Vec<u8>> = (0..N)
        .map(|i| serial.query(&facts_drill(i as i64)).unwrap().batch.encode())
        .collect();

    // the same N queries, overlapping on one fresh cluster
    let (_, client) = facts_client(WorkerConfig { num_workers: 2, ..WorkerConfig::test() });
    let got: Vec<(usize, theseus::cluster::QueryResult)> = std::thread::scope(|s| {
        let client = &client;
        let handles: Vec<_> = (0..N)
            .map(|i| s.spawn(move || (i, client.query(&facts_drill(i as i64)).unwrap())))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut per_query_total = 0u64;
    for (i, r) in &got {
        assert_eq!(
            r.batch.encode(),
            want[*i],
            "query {i}: concurrent bytes differ from serial"
        );
        assert!(tasks_of(r) > 0, "query {i} must report its own tasks");
        assert_eq!(r.worker_stats.len(), 2);
        per_query_total += tasks_of(r);
    }
    // per-qid scopes partition the global executed counter exactly:
    // every completion lands in exactly one query's scope
    let global: u64 = client
        .gateway()
        .cluster
        .workers
        .iter()
        .map(|w| w.compute.executed())
        .sum();
    assert_eq!(
        global, per_query_total,
        "per-query task counts must sum to the cluster total (no bleed, no loss)"
    );
    assert_eq!(
        client.gateway().cluster.metrics.counter_value("gateway.admitted"),
        N as u64
    );
}

/// Admission under a budget that fits exactly one query: all
/// submissions beyond the first queue (visible on `gateway.queued`),
/// every queued query is eventually admitted and returns correct
/// bytes, and the aggregate admitted footprint provably never exceeds
/// the budget (`gateway.admission_peak_bytes` ≤ capacity).
#[test]
fn tiny_admission_budget_queues_retries_and_bounds_footprint() {
    const N: usize = 4;
    let store = SimObjectStore::in_memory(&SimContext::test());
    write_int_fact(&*store, 2, 1200);
    let total: u64 = store
        .list("facts/")
        .unwrap()
        .iter()
        .map(|k| store.head(k).unwrap())
        .sum();
    let per_worker = (total / 2).max(1) as usize; // == the gateway's own sizing
    let plain = connect(
        WorkerConfig { num_workers: 2, ..WorkerConfig::test() },
        store.clone(),
        None,
    )
    .unwrap();
    let want: Vec<Vec<u8>> = (0..N)
        .map(|i| plain.query(&facts_drill(i as i64)).unwrap().batch.encode())
        .collect();

    let client = connect(
        WorkerConfig {
            num_workers: 2,
            admission_capacity_bytes: per_worker, // exactly one query fits
            ..WorkerConfig::test()
        },
        store,
        None,
    )
    .unwrap();
    let gw = client.gateway();
    // occupy the whole budget so every submission must queue first
    let gate = gw.admission.admit(0, per_worker, Duration::from_secs(5)).unwrap();
    let got: Vec<(usize, Vec<u8>)> = std::thread::scope(|s| {
        let client = &client;
        let handles: Vec<_> = (0..N)
            .map(|i| {
                s.spawn(move || {
                    let r = client.query(&facts_drill(i as i64)).unwrap();
                    (i, r.batch.encode())
                })
            })
            .collect();
        let deadline = Instant::now() + Duration::from_secs(10);
        while gw.admission.waiting() < N {
            assert!(Instant::now() < deadline, "submissions never queued");
            std::thread::sleep(Duration::from_millis(2));
        }
        drop(gate); // budget frees: the queue drains one at a time
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, bytes) in &got {
        assert_eq!(*bytes, want[*i], "queued query {i} returned wrong bytes");
    }
    let m = &gw.cluster.metrics;
    assert_eq!(m.counter_value("gateway.queued"), N as u64, "all four parked");
    assert_eq!(
        m.counter_value("gateway.admitted"),
        N as u64 + 1,
        "the gate grant plus every queued query"
    );
    let peak = m.gauge_value("gateway.admission_peak_bytes");
    assert!(
        peak > 0 && peak <= per_worker as i64,
        "aggregate admitted footprint must stay under the budget ({peak} vs {per_worker})"
    );
    assert!(m.histogram("gateway.admission_wait_ms").count() >= N as u64);
    assert_eq!(gw.admission.reserved_bytes(), 0, "all grants returned");
}

/// A high-priority session submitted *after* a batch backlog admits
/// first (priority classes order the queue), while the batch class
/// itself stays FIFO. Arrival order is pinned by waiting-count
/// barriers, admission order is observed through the serialized
/// budget, so the assertion is deterministic.
#[test]
fn high_priority_session_admits_before_earlier_batch_waiters() {
    let metrics = Arc::new(Metrics::default());
    let ctl = AdmissionController::new(1000, 4, metrics);
    let gate = ctl.admit(0, 1000, Duration::from_secs(5)).unwrap();
    let order = Arc::new(std::sync::Mutex::new(Vec::<&'static str>::new()));
    std::thread::scope(|s| {
        let mut arrived = 0usize;
        let mut arrive = |name: &'static str, priority: i64| {
            let ctl = ctl.clone();
            let order = order.clone();
            s.spawn(move || {
                let g = ctl.admit(priority, 1000, Duration::from_secs(10)).unwrap();
                order.lock().unwrap().push(name);
                drop(g);
            });
            arrived += 1;
            let deadline = Instant::now() + Duration::from_secs(10);
            while ctl.waiting() < arrived {
                assert!(Instant::now() < deadline, "{name} never queued");
                std::thread::sleep(Duration::from_millis(2));
            }
        };
        // two batch queries arrive first, then the interactive session
        arrive("batch-a", 0);
        arrive("batch-b", 0);
        arrive("interactive", 9);
        drop(gate);
    });
    let order = Arc::try_unwrap(order).unwrap().into_inner().unwrap();
    assert_eq!(
        order,
        vec!["interactive", "batch-a", "batch-b"],
        "priority admits past the backlog; the batch class stays FIFO"
    );
}

/// RAII per-query cleanup (PR 10's `QueryScope`): a worker panic
/// mid-query must tear down every per-query trace on the error path —
/// the admission reservation comes back, every worker's governor ledger
/// returns to zero — and the same cluster must then answer the same
/// query byte-identically. The seed's failure path returned early and
/// left the panicked query's holders and reservations behind.
#[test]
fn mid_query_panic_leaves_no_residue() {
    let (_store, client) =
        facts_client(WorkerConfig { num_workers: 2, ..WorkerConfig::test() });
    let q = facts_drill(0);
    let baseline = client.query(&q).unwrap();
    let gw = client.gateway();

    gw.cluster.workers[1].inject_panic_next();
    let err = client.query(&q).unwrap_err();

    // the panicked query's reservations drain as its tasks unwind;
    // poll briefly instead of racing the executor threads
    let deadline = Instant::now() + Duration::from_secs(5);
    let leaked = |gw: &theseus::cluster::Gateway| -> usize {
        gw.admission.reserved_bytes() as usize
            + gw.cluster.workers.iter().map(|w| w.ctx.governor.reserved()).sum::<usize>()
    };
    while leaked(gw) != 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }

    // CI failure artifact, written before any assertion can panic
    let _ = std::fs::create_dir_all("target");
    let _ = std::fs::write(
        "target/query_scope_residue_metrics.txt",
        format!(
            "error: {err}\nadmission reserved: {}\ngovernor reserved: {:?}\n\n{}",
            gw.admission.reserved_bytes(),
            gw.cluster.workers.iter().map(|w| w.ctx.governor.reserved()).collect::<Vec<_>>(),
            gw.cluster.metrics.snapshot()
        ),
    );

    assert!(
        matches!(err, theseus::Error::WorkerPanic { .. }),
        "panic must surface as WorkerPanic (not retried): {err}"
    );
    assert_eq!(gw.admission.reserved_bytes(), 0, "admission grant leaked");
    for w in &gw.cluster.workers {
        assert_eq!(
            w.ctx.governor.reserved(),
            0,
            "worker {} governor ledger leaked",
            w.ctx.worker_id
        );
    }
    assert!(gw.cluster.metrics.counter_value("gateway.worker_panic_total") >= 1);

    let after = client.query(&q).unwrap();
    assert_eq!(
        after.batch.encode(),
        baseline.batch.encode(),
        "cluster must stay healthy after the contained panic"
    );
}
